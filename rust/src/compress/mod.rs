//! The compression layer: a staged codec pipeline.
//!
//! Every compressor here is a composition of three stages — predictor
//! × quantizer × entropy/packing coder — identified by a
//! [`codec::CodecSpec`] and built via [`codec::CodecSpec::build`] (see
//! [`codec`] for the stage catalogue). Two canonical compositions keep
//! their historical stream formats and named types, matching the two
//! families the paper contrasts:
//!
//! * [`cuszp::CuszpLike`] — **error-bounded** (cuSZp-class): prequant +
//!   integer 1D Lorenzo + per-block fixed-length bit packing. Output
//!   size is data-dependent (unknown ahead of time); pointwise error is
//!   guaranteed ≤ the absolute bound. This is what gZCCL uses.
//! * [`fixed_rate::FixedRate`] — **fixed-rate** (1D-ZFP-class, the
//!   CPRP2P baseline): per-block scaled truncation to a fixed bit
//!   budget. Output size is known ahead of time; error is *unbounded*
//!   (scales with block magnitude), which is exactly the accuracy
//!   hazard the paper attributes to prior work.
//!
//! Two more canonical compositions extend the family:
//! [`codec::CodecSpec::lossless`] (zero distortion — the tier that
//! turns "compression vetoed" workloads into wins) and
//! [`codec::CodecSpec::rle_rice`] (an entropy-coded error-bounded
//! pipeline: slower kernels, higher ratio). Streams are
//! self-describing; [`codec::decode_any`] decodes any of them from the
//! magic alone.
//!
//! All of them compress real bytes — compression ratios and accuracy
//! results in the experiments are genuine, not modeled. Only GPU
//! *timing* comes from the cost model ([`crate::gpu::KernelModel`]).

pub mod bitpack;
pub mod codec;
pub mod cuszp;
pub mod fixed_rate;
pub mod profile;

pub use codec::{decode_any, CodecSpec, CoderKind, PredictorKind, QuantizerKind};
pub use cuszp::CuszpLike;
pub use fixed_rate::FixedRate;
pub use profile::CompressionProfile;

use crate::error::Result;

/// A lossy floating-point compressor.
pub trait Compressor: Send + Sync {
    /// Human-readable name (used in reports).
    fn name(&self) -> &'static str;

    /// Compress `data` into a self-describing byte stream.
    fn compress(&self, data: &[f32]) -> Vec<u8>;

    /// Decompress a stream produced by [`Compressor::compress`].
    fn decompress(&self, stream: &[u8]) -> Result<Vec<f32>>;

    /// Whether the pointwise absolute error is guaranteed bounded.
    fn is_error_bounded(&self) -> bool;

    /// The absolute error bound, if [`Compressor::is_error_bounded`].
    fn error_bound(&self) -> Option<f64>;

    /// Exact output size for `n` input values, if pre-known (fixed-rate
    /// compressors only — this property is what lets CPRP2P pre-post
    /// receives, and what costs it bounded accuracy).
    fn fixed_output_size(&self, n: usize) -> Option<usize>;

    /// A variant of this compressor rebound to a different absolute
    /// error bound — what lets one [`crate::coordinator::RankCtx`] run
    /// different legs of an execution plan at different bounds.
    /// `None` when the family has no per-call bound to rebind
    /// (fixed-rate) or `eb` is not a usable bound.
    fn rebound(&self, eb: f64) -> Option<std::sync::Arc<dyn Compressor>> {
        let _ = eb;
        None
    }

    /// The staged-pipeline identity of this compressor, when it is one
    /// of the built-in codec compositions ([`CodecSpec::build`]).
    /// `None` for custom implementations — per-leg codec rebinding
    /// then falls back to [`Compressor::rebound`].
    fn spec(&self) -> Option<CodecSpec> {
        None
    }
}

/// Compression ratio of a (raw, compressed) pair in bytes.
pub fn ratio(raw_bytes: usize, compressed_bytes: usize) -> f64 {
    if compressed_bytes == 0 {
        f64::INFINITY
    } else {
        raw_bytes as f64 / compressed_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_basics() {
        assert_eq!(ratio(100, 10), 10.0);
        assert_eq!(ratio(100, 0), f64::INFINITY);
    }
}
