//! cuSZp-style error-bounded lossy compressor.
//!
//! Follows the cuSZp (SC '23) pipeline that gZCCL builds on:
//!
//! 1. **Prequantization**: `q[i] = round(x[i] / (2·eb))` — after this
//!    step every reconstruction `q[i]·2·eb` is within `eb` of `x[i]`.
//! 2. **Integer 1D Lorenzo**: per 32-element block, `d[0] = q[0]`,
//!    `d[i] = q[i] − q[i−1]` — exact integer deltas, so no error
//!    accumulation beyond the prequant rounding.
//! 3. **Fixed-length encoding**: per block, the maximum significant bit
//!    width of the zigzagged deltas is stored, then every delta is
//!    packed at exactly that width.
//!
//! Blocks are independently decodable (the first delta is absolute),
//! which is what makes cuSZp massively parallel on GPU and what lets
//! gZCCL decode sub-ranges with multi-stream kernels. Blocks whose
//! quantized values would overflow (huge magnitudes or eb ≪ data range)
//! fall back to verbatim f32 storage — lossless for that block.
//!
//! The output size is data-dependent (error-bounded compressors cannot
//! pre-commit to a size); the coordinator learns it only after the
//! kernel completes, exactly the property the paper designs around.

use crate::error::{Error, Result};

use super::bitpack::{
    bit_width, pack_fixed_into, read_varint, unpack_fixed_into, unzigzag, write_varint,
};
use super::codec::{prequant_accumulate, prequant_symbols, CodecSpec};
use super::Compressor;

/// Values per encode block (cuSZp uses 32 per thread).
pub const BLOCK: usize = 32;

/// Stream magic: "GZCP".
const MAGIC: [u8; 4] = *b"GZCP";
/// Format version.
const VERSION: u8 = 1;
/// Width byte marking a verbatim-f32 fallback block.
const RAW_BLOCK: u8 = 0xFF;
/// Header: magic(4) + version(1) + eb(8) + count(8).
const HEADER: usize = 21;

/// Error-bounded cuSZp-like compressor with absolute bound `eb`.
///
/// The canonical `{Lorenzo1D, Prequant, Bitpack}` composition of the
/// staged pipeline ([`CodecSpec::cuszp`]): the prequant + Lorenzo
/// stages are the shared functions in [`super::codec`], so this stream
/// format stays byte-for-byte what it always was while every other
/// composition reuses the same arithmetic.
#[derive(Debug, Clone, Copy)]
pub struct CuszpLike {
    eb: f64,
}

impl CuszpLike {
    /// Construct with absolute error bound `eb` (> 0).
    pub fn new(eb: f64) -> Self {
        assert!(eb > 0.0 && eb.is_finite(), "error bound must be positive");
        CuszpLike { eb }
    }

    /// The absolute error bound.
    pub fn eb(&self) -> f64 {
        self.eb
    }

    /// Compress one 32-value (or shorter, final) block.
    ///
    /// Layout per block: `varint(zigzag(q[0]))` (the absolute base,
    /// which keeps blocks independently decodable for multi-stream
    /// decode) followed by the remaining deltas packed at the block's
    /// max bit width. Separating the base from the deltas keeps the
    /// packed width small on smooth data whose absolute magnitude is
    /// large — the common case for wavefields.
    fn encode_block(&self, block: &[f32], widths: &mut Vec<u8>, payload: &mut Vec<u8>) {
        // Stages 1+2 (prequant + Lorenzo) are the shared pipeline
        // functions; `None` means quantization overflowed.
        let symbols = match prequant_symbols(block, self.eb, true) {
            Some(s) => s,
            None => {
                // Verbatim block: lossless f32 storage.
                widths.push(RAW_BLOCK);
                for &x in block {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
                return;
            }
        };
        let maxw = symbols[1..].iter().map(|&z| bit_width(z)).max().unwrap_or(0);
        if maxw > 28 {
            widths.push(RAW_BLOCK);
            for &x in block {
                payload.extend_from_slice(&x.to_le_bytes());
            }
            return;
        }
        widths.push(maxw as u8);
        write_varint(payload, symbols[0]);
        if maxw > 0 && block.len() > 1 {
            pack_fixed_into(&symbols[1..], maxw, payload);
        }
    }

    fn decode_block(
        &self,
        width: u8,
        count: usize,
        payload: &[u8],
        cursor: &mut usize,
        out: &mut Vec<f32>,
        scratch: &mut Vec<u32>,
    ) -> Result<()> {
        let two_eb = 2.0 * self.eb;
        if width == RAW_BLOCK {
            let need = count * 4;
            let slice = payload
                .get(*cursor..*cursor + need)
                .ok_or_else(|| Error::compress("truncated raw block"))?;
            for ch in slice.chunks_exact(4) {
                out.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            *cursor += need;
            return Ok(());
        }
        let width = width as u32;
        if width > 28 {
            return Err(Error::compress(format!("invalid block width {width}")));
        }
        let base = read_varint(payload, cursor)
            .ok_or_else(|| Error::compress("truncated block base"))?;
        let two_eb_f32 = two_eb as f32;
        let rest = count - 1;
        if width == 0 {
            // All remaining deltas are zero: constant block.
            let v = unzigzag(base) as i64 as f32 * two_eb_f32;
            out.push(v);
            out.extend(std::iter::repeat(v).take(rest));
            return Ok(());
        }
        scratch.clear();
        let nbytes = unpack_fixed_into(&payload[*cursor..], rest, width, scratch)
            .ok_or_else(|| Error::compress("truncated packed block"))?;
        // Stage inverses are shared with the pipeline: f32
        // reconstruction is exact in the integer part for |q| < 2^24
        // (always true on the packed path: widths ≤ 28 and prequant
        // guards the range) and ~1 ulp otherwise.
        prequant_accumulate(base, scratch, true, two_eb_f32, out);
        *cursor += nbytes;
        Ok(())
    }
}

impl Compressor for CuszpLike {
    fn name(&self) -> &'static str {
        "cuszp-like(eb)"
    }

    fn compress(&self, data: &[f32]) -> Vec<u8> {
        let nblocks = data.len().div_ceil(BLOCK);
        let mut widths = Vec::with_capacity(nblocks);
        let mut payload = Vec::with_capacity(data.len() / 2 + 64);
        for block in data.chunks(BLOCK) {
            self.encode_block(block, &mut widths, &mut payload);
        }
        let mut out = Vec::with_capacity(HEADER + widths.len() + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&self.eb.to_le_bytes());
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&widths);
        out.extend_from_slice(&payload);
        out
    }

    fn decompress(&self, stream: &[u8]) -> Result<Vec<f32>> {
        if stream.len() < HEADER || stream[0..4] != MAGIC {
            return Err(Error::compress("bad magic / truncated header"));
        }
        if stream[4] != VERSION {
            return Err(Error::compress(format!("unknown version {}", stream[4])));
        }
        let eb = f64::from_le_bytes(stream[5..13].try_into().unwrap());
        if (eb - self.eb).abs() > f64::EPSILON * eb.abs() {
            // Streams carry their own eb; decode with the stream's.
            return CuszpLike::new(eb).decompress(stream);
        }
        let n = u64::from_le_bytes(stream[13..21].try_into().unwrap()) as usize;
        let nblocks = n.div_ceil(BLOCK);
        let widths = stream
            .get(HEADER..HEADER + nblocks)
            .ok_or_else(|| Error::compress("truncated width table"))?;
        let payload = &stream[HEADER + nblocks..];
        let mut out = Vec::with_capacity(n);
        let mut cursor = 0usize;
        let mut scratch: Vec<u32> = Vec::with_capacity(BLOCK);
        for (b, &w) in widths.iter().enumerate() {
            let count = if b + 1 == nblocks && n % BLOCK != 0 {
                n % BLOCK
            } else {
                BLOCK
            };
            self.decode_block(w, count, payload, &mut cursor, &mut out, &mut scratch)?;
        }
        Ok(out)
    }

    fn is_error_bounded(&self) -> bool {
        true
    }

    fn error_bound(&self) -> Option<f64> {
        Some(self.eb)
    }

    fn fixed_output_size(&self, _n: usize) -> Option<usize> {
        None
    }

    fn rebound(&self, eb: f64) -> Option<std::sync::Arc<dyn Compressor>> {
        // The bound is a per-call constructor argument, so any positive
        // finite eb rebinds; streams are self-describing (the header
        // carries eb), so decoders never need the rebound instance.
        if eb > 0.0 && eb.is_finite() {
            Some(std::sync::Arc::new(CuszpLike::new(eb)))
        } else {
            None
        }
    }

    fn spec(&self) -> Option<CodecSpec> {
        Some(CodecSpec::cuszp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, max_abs_diff, Cases, Pcg32};

    fn round_trip(c: &CuszpLike, data: &[f32]) -> Vec<f32> {
        c.decompress(&c.compress(data)).unwrap()
    }

    #[test]
    fn empty_input() {
        let c = CuszpLike::new(1e-4);
        assert_eq!(round_trip(&c, &[]), Vec::<f32>::new());
    }

    #[test]
    fn constant_data_compresses_hard() {
        let c = CuszpLike::new(1e-4);
        let data = vec![3.14159f32; 100_000];
        let stream = c.compress(&data);
        // Each block stores a varint base + zero-width deltas: ≫25×.
        assert!(
            stream.len() < data.len() * 4 / 25,
            "stream {} bytes",
            stream.len()
        );
        let back = c.decompress(&stream).unwrap();
        assert!(max_abs_diff(&back, &data) <= 1e-4);
    }

    #[test]
    fn smooth_data_error_bounded() {
        let c = CuszpLike::new(1e-3);
        let data: Vec<f32> = (0..10_000)
            .map(|i| (i as f32 * 0.001).sin() * 2.0)
            .collect();
        let back = round_trip(&c, &data);
        assert!(max_abs_diff(&back, &data) <= 1e-3 + 1e-6);
        let stream = c.compress(&data);
        assert!(super::super::ratio(data.len() * 4, stream.len()) > 4.0);
    }

    #[test]
    fn random_data_still_bounded() {
        let mut rng = Pcg32::seeded(3);
        let data = rng.uniform_vec(5000, -10.0, 10.0);
        let c = CuszpLike::new(1e-2);
        let back = round_trip(&c, &data);
        assert!(max_abs_diff(&back, &data) <= 1e-2 + 1e-5);
    }

    #[test]
    fn partial_final_block() {
        let c = CuszpLike::new(1e-4);
        for n in [1usize, 31, 32, 33, 63, 65] {
            let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
            let back = round_trip(&c, &data);
            assert_eq!(back.len(), n);
            assert!(max_abs_diff(&back, &data) <= 1e-4 + 1e-7);
        }
    }

    #[test]
    fn rebound_runs_at_the_new_bound() {
        let base = CuszpLike::new(1e-4);
        let loose = base.rebound(1e-2).expect("error-bounded rebinds");
        let data: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.01).sin()).collect();
        let stream = loose.compress(&data);
        // The rebound instance quantizes at ITS bound, not the base's:
        // the loose stream is measurably smaller and its error sits
        // between the two bounds.
        assert!(stream.len() < base.compress(&data).len());
        let back = base.decompress(&stream).unwrap(); // self-describing
        let err = max_abs_diff(&back, &data);
        assert!(err <= 1e-2 + 1e-5, "err {err}");
        assert!(err > 1e-4, "loose stream should exceed the tight bound");
        // Degenerate bounds do not rebind.
        assert!(base.rebound(0.0).is_none());
        assert!(base.rebound(f64::NAN).is_none());
    }

    #[test]
    fn raw_fallback_on_huge_values() {
        let c = CuszpLike::new(1e-9);
        // eb tiny vs magnitude → quantization overflows → raw block.
        let data = vec![1e30f32, -1e30, 5e29, 0.0];
        let back = round_trip(&c, &data);
        // Raw fallback is lossless.
        assert_eq!(back, data);
    }

    #[test]
    fn nan_falls_back_lossless() {
        let c = CuszpLike::new(1e-4);
        let data = vec![1.0f32, f32::NAN, 2.0];
        let back = round_trip(&c, &data);
        assert_eq!(back[0], 1.0);
        assert!(back[1].is_nan());
        assert_eq!(back[2], 2.0);
    }

    #[test]
    fn stream_carries_its_own_eb() {
        let c1 = CuszpLike::new(1e-3);
        let data: Vec<f32> = (0..100).map(|i| (i as f32).sqrt()).collect();
        let stream = c1.compress(&data);
        // Decompress with a differently-configured instance.
        let c2 = CuszpLike::new(5e-2);
        let back = c2.decompress(&stream).unwrap();
        assert!(max_abs_diff(&back, &data) <= 1e-3 + 1e-6);
    }

    #[test]
    fn corrupt_stream_rejected() {
        let c = CuszpLike::new(1e-4);
        assert!(c.decompress(b"nope").is_err());
        let mut s = c.compress(&[1.0, 2.0, 3.0]);
        s.truncate(s.len() - 1);
        assert!(c.decompress(&s).is_err());
        let mut s2 = c.compress(&[1.0f32; 64]);
        s2[0] = b'X';
        assert!(c.decompress(&s2).is_err());
    }

    #[test]
    fn tighter_bound_bigger_stream() {
        let mut rng = Pcg32::seeded(17);
        // Smooth-ish signal.
        let mut data = vec![0.0f32; 20_000];
        let mut acc = 0.0f32;
        for x in data.iter_mut() {
            acc += rng.next_gaussian() * 0.01;
            *x = acc;
        }
        let loose = CuszpLike::new(1e-2).compress(&data).len();
        let tight = CuszpLike::new(1e-5).compress(&data).len();
        assert!(tight > loose, "tight {tight} loose {loose}");
    }

    #[test]
    fn prop_error_bound_holds_for_random_inputs() {
        forall(
            Cases::n(40),
            |rng| {
                let n = rng.range_usize(0, 600);
                let eb = *rng.choose(&[1e-2, 1e-3, 1e-4]);
                let scale = rng.range_f32(0.1, 100.0);
                let data: Vec<f32> = (0..n)
                    .map(|_| rng.next_gaussian() * scale)
                    .collect();
                (eb, data)
            },
            |(eb, data)| {
                let c = CuszpLike::new(*eb);
                let back = c.decompress(&c.compress(data)).map_err(|e| e.to_string())?;
                if back.len() != data.len() {
                    return Err("length mismatch".into());
                }
                for (i, (a, b)) in back.iter().zip(data.iter()).enumerate() {
                    // eb plus f32 representation rounding of the
                    // reconstructed magnitude.
                    let tol = *eb as f32 + b.abs() * 4.0 * f32::EPSILON;
                    if (a - b).abs() > tol {
                        return Err(format!("bound violated at {i}: {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_idempotent_on_reconstructed_data() {
        // Compressing already-reconstructed data loses nothing more:
        // the second pass maps each value to the same quantization bin.
        forall(
            Cases::n(20),
            |rng| {
                let n = rng.range_usize(1, 300);
                let data: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
                data
            },
            |data| {
                let c = CuszpLike::new(1e-3);
                let once = c.decompress(&c.compress(data)).unwrap();
                let twice = c.decompress(&c.compress(&once)).unwrap();
                for (a, b) in once.iter().zip(twice.iter()) {
                    // Bin centers re-quantize to themselves (allow fp fuzz).
                    if (a - b).abs() > 1e-3 * 1e-3 {
                        return Err(format!("not idempotent: {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}
