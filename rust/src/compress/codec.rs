//! The staged codec pipeline: predictor × quantizer × coder.
//!
//! A codec is a composition of three stages:
//!
//! 1. **Predictor** — `None` (symbols are the quantized values
//!    themselves) or `Lorenzo1D` (per-block first-order deltas, the
//!    cuSZp predictor; the first symbol stays absolute so blocks remain
//!    independently decodable).
//! 2. **Quantizer** — `Prequant` (error-bounded `round(x / 2eb)`),
//!    `FixedRate(bits)` (per-block scaled truncation, unbounded error),
//!    or `Lossless` (identity on the f32 bit patterns — zero
//!    distortion).
//! 3. **Coder** — `Bitpack` (per-block max-width fixed packing),
//!    `Byteplane` (cheap byte-plane split, all-zero high planes
//!    dropped), or `RleRice` (zero-run RLE + Rice coding with a
//!    per-block parameter — a real entropy coder).
//!
//! [`CuszpLike`] is the canonical `{Lorenzo1D, Prequant, Bitpack}`
//! composition and [`FixedRate`] the canonical
//! `{None, FixedRate(bits), Bitpack}` one; both keep their historical
//! stream formats (`GZCP` / `GZFR`) byte-for-byte, built from the
//! shared stage functions in this module. Every other composition is
//! realized by the private `Staged` compressor over a self-describing
//! `GZCX` container whose header carries the spec, so any stream built
//! here decodes via [`decode_any`] without knowing the producer.
//!
//! [`CodecSpec`] is the *identity* threaded through the planning stack:
//! `LegExec` carries one per leg, the cost model prices its stages, and
//! the tuner picks it per leg from stage throughput vs. link speed.

use std::sync::Arc;

use crate::error::{Error, Result};

use super::bitpack::{
    bit_width, pack_fixed_into, read_varint, unpack_fixed_into, unzigzag, write_varint, zigzag,
    BitReader, BitWriter,
};
use super::cuszp::BLOCK;
use super::{Compressor, CuszpLike, FixedRate};

/// Stream magic of the generic staged container: "GZCX".
const MAGIC: [u8; 4] = *b"GZCX";
/// Container format version.
const VERSION: u8 = 1;
/// Header: magic(4) + version(1) + predictor(1) + quantizer(1) +
/// quantizer bits(1) + coder(1) + eb(8) + count(8).
const HEADER: usize = 25;
/// Tag byte marking a verbatim-f32 fallback block.
const RAW_BLOCK: u8 = 0xFF;
/// Unary quotient cap of the Rice coder: at this many leading ones the
/// value is stored verbatim in 32 bits (bounds pathological symbols).
const RICE_ESCAPE: u32 = 20;
/// Largest selectable per-block Rice parameter.
const RICE_K_MAX: u32 = 24;
/// Fixed Rice parameter for zero-run lengths (runs are short: ≤ 31).
const ZRUN_K: u32 = 2;

/// Prediction stage of a codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PredictorKind {
    /// No prediction: symbols are the quantized values themselves.
    None,
    /// Per-block integer 1D Lorenzo (first-order deltas).
    Lorenzo1D,
}

/// Quantization stage of a codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantizerKind {
    /// Error-bounded prequantization `round(x / 2eb)`.
    Prequant,
    /// Per-block scaled truncation at a fixed bit budget (unbounded
    /// absolute error — the CPRP2P hazard).
    FixedRate(u8),
    /// Identity on the f32 bit patterns: zero distortion.
    Lossless,
}

/// Entropy/packing stage of a codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoderKind {
    /// Per-block max-significant-width fixed packing (cuSZp's encoder).
    Bitpack,
    /// Byte-plane split; all-zero high planes are dropped per block.
    Byteplane,
    /// Zero-run RLE + Rice coding with a per-block parameter.
    RleRice,
}

/// The identity of a staged codec: one pick per stage.
///
/// This is what [`crate::topo::LegExec`] carries per leg and what the
/// cost model prices stage-by-stage. [`CodecSpec::build`] turns it into
/// a live [`Compressor`]; the canonical compositions come back as the
/// historical [`CuszpLike`] / [`FixedRate`] stream formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CodecSpec {
    /// Prediction stage.
    pub predictor: PredictorKind,
    /// Quantization stage.
    pub quantizer: QuantizerKind,
    /// Entropy/packing stage.
    pub coder: CoderKind,
}

impl CodecSpec {
    /// The canonical cuSZp-like pipeline: Lorenzo + prequant + bitpack
    /// (the `GZCP` stream format).
    pub fn cuszp() -> Self {
        CodecSpec {
            predictor: PredictorKind::Lorenzo1D,
            quantizer: QuantizerKind::Prequant,
            coder: CoderKind::Bitpack,
        }
    }

    /// The canonical fixed-rate pipeline at `bits` per value (the
    /// `GZFR` stream format).
    pub fn fixed_rate(bits: u8) -> Self {
        CodecSpec {
            predictor: PredictorKind::None,
            quantizer: QuantizerKind::FixedRate(bits),
            coder: CoderKind::Bitpack,
        }
    }

    /// The canonical lossless tier: Lorenzo over the f32 bit patterns,
    /// byte-plane packed. Zero distortion at modest ratios — what turns
    /// "compression vetoed" workloads into compression wins.
    pub fn lossless() -> Self {
        CodecSpec {
            predictor: PredictorKind::Lorenzo1D,
            quantizer: QuantizerKind::Lossless,
            coder: CoderKind::Byteplane,
        }
    }

    /// The entropy-coded error-bounded pipeline: cuSZp's prequant +
    /// Lorenzo stages with zero-run RLE + Rice coding — slower kernels,
    /// higher ratio, the pick for oversubscribed uplinks.
    pub fn rle_rice() -> Self {
        CodecSpec {
            predictor: PredictorKind::Lorenzo1D,
            quantizer: QuantizerKind::Prequant,
            coder: CoderKind::RleRice,
        }
    }

    /// Whether the quantizer is the zero-distortion lossless tier.
    pub fn is_lossless(&self) -> bool {
        self.quantizer == QuantizerKind::Lossless
    }

    /// Whether the quantizer is the fixed-rate family (unbounded
    /// absolute error, pre-known output size).
    pub fn is_fixed_rate(&self) -> bool {
        matches!(self.quantizer, QuantizerKind::FixedRate(_))
    }

    /// Whether the pointwise absolute error is bounded (prequant at its
    /// eb; lossless at zero).
    pub fn is_error_bounded(&self) -> bool {
        !self.is_fixed_rate()
    }

    /// Every composition of the three stages (fixed-rate quantizers at
    /// `bits`) — the property-test and bench cross-product.
    pub fn compositions(bits: u8) -> Vec<CodecSpec> {
        let mut out = Vec::with_capacity(18);
        for predictor in [PredictorKind::None, PredictorKind::Lorenzo1D] {
            for quantizer in [
                QuantizerKind::Prequant,
                QuantizerKind::FixedRate(bits),
                QuantizerKind::Lossless,
            ] {
                for coder in [CoderKind::Bitpack, CoderKind::Byteplane, CoderKind::RleRice] {
                    out.push(CodecSpec {
                        predictor,
                        quantizer,
                        coder,
                    });
                }
            }
        }
        out
    }

    /// Compact display label: canonical names for the canonical
    /// compositions, a `predictor+quantizer+coder` triple otherwise.
    /// [`CodecSpec::parse`] accepts every label this produces.
    pub fn label(&self) -> String {
        if *self == Self::cuszp() {
            return "cuszp".into();
        }
        if *self == Self::lossless() {
            return "lossless".into();
        }
        if *self == Self::rle_rice() {
            return "rle-rice".into();
        }
        if let QuantizerKind::FixedRate(b) = self.quantizer {
            if *self == Self::fixed_rate(b) {
                return format!("fixed{b}");
            }
        }
        let p = match self.predictor {
            PredictorKind::None => "none",
            PredictorKind::Lorenzo1D => "lorenzo",
        };
        let q = match self.quantizer {
            QuantizerKind::Prequant => "prequant".to_string(),
            QuantizerKind::FixedRate(b) => format!("fixed{b}"),
            QuantizerKind::Lossless => "lossless".to_string(),
        };
        let c = match self.coder {
            CoderKind::Bitpack => "bitpack",
            CoderKind::Byteplane => "byteplane",
            CoderKind::RleRice => "rice",
        };
        format!("{p}+{q}+{c}")
    }

    /// Parse a codec label: a canonical name (`cuszp`, `lossless`,
    /// `rle-rice`, `fixed<bits>`) or a `predictor+quantizer+coder`
    /// triple (`lorenzo+prequant+rice`). Inverse of
    /// [`CodecSpec::label`].
    pub fn parse(s: &str) -> Option<CodecSpec> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "cuszp" | "cuszp-like" => return Some(Self::cuszp()),
            "lossless" | "bitexact" => return Some(Self::lossless()),
            "rle-rice" | "rle_rice" | "rice" => return Some(Self::rle_rice()),
            _ => {}
        }
        if let Some(rest) = t.strip_prefix("fixed") {
            if !rest.contains('+') {
                return rest
                    .parse::<u8>()
                    .ok()
                    .filter(|b| (2..=28).contains(b))
                    .map(Self::fixed_rate);
            }
        }
        let parts: Vec<&str> = t.split('+').collect();
        let [p, q, c] = parts.as_slice() else {
            return None;
        };
        let predictor = match *p {
            "none" => PredictorKind::None,
            "lorenzo" => PredictorKind::Lorenzo1D,
            _ => return None,
        };
        let quantizer = match *q {
            "prequant" => QuantizerKind::Prequant,
            "lossless" => QuantizerKind::Lossless,
            other => {
                let bits = other.strip_prefix("fixed")?.parse::<u8>().ok()?;
                if !(2..=28).contains(&bits) {
                    return None;
                }
                QuantizerKind::FixedRate(bits)
            }
        };
        let coder = match *c {
            "bitpack" => CoderKind::Bitpack,
            "byteplane" => CoderKind::Byteplane,
            "rice" | "rle-rice" | "rle_rice" => CoderKind::RleRice,
            _ => return None,
        };
        Some(CodecSpec {
            predictor,
            quantizer,
            coder,
        })
    }

    /// Build a live compressor for this composition. `eb` is the
    /// absolute bound for prequant quantizers (ignored by the lossless
    /// and fixed-rate tiers). `None` when the composition is not
    /// buildable: a prequant quantizer with a non-positive or
    /// non-finite `eb`, or fixed-rate bits outside `2..=28`.
    pub fn build(&self, eb: f64) -> Option<Arc<dyn Compressor>> {
        if *self == Self::cuszp() {
            return (eb > 0.0 && eb.is_finite())
                .then(|| Arc::new(CuszpLike::new(eb)) as Arc<dyn Compressor>);
        }
        if let QuantizerKind::FixedRate(bits) = self.quantizer {
            if !(2..=28).contains(&bits) {
                return None;
            }
            if *self == Self::fixed_rate(bits) {
                return Some(Arc::new(FixedRate::new(bits as u32)));
            }
        }
        if self.quantizer == QuantizerKind::Prequant && !(eb > 0.0 && eb.is_finite()) {
            return None;
        }
        let eb = if self.quantizer == QuantizerKind::Prequant {
            eb
        } else {
            0.0
        };
        Some(Arc::new(Staged { spec: *self, eb }))
    }
}

// ---------------------------------------------------------------------
// Shared stage functions (the canonical compressors route through
// these, so their stream formats stay byte-for-byte).
// ---------------------------------------------------------------------

/// Prequant + optional Lorenzo over one block: zigzagged symbols, the
/// first absolute. `None` when quantization overflows (raw fallback).
/// Exactly the arithmetic of the historical `CuszpLike` encoder.
pub(crate) fn prequant_symbols(block: &[f32], eb: f64, lorenzo: bool) -> Option<Vec<u32>> {
    // Multiply by the reciprocal instead of dividing: measurably faster
    // and bit-identical to the Pallas kernel's arithmetic.
    let inv_two_eb = 1.0 / (2.0 * eb);
    let inv_f32 = inv_two_eb as f32;
    let mut symbols = Vec::with_capacity(block.len());
    let mut prev: i64 = 0;
    for &x in block {
        // f32 fast path (exact for |q| < 2^23, the overwhelmingly
        // common case); recompute in f64 near the edge, and treat
        // non-finite inputs / i32 overflow as raw-block triggers.
        let qf = (x * inv_f32).round();
        let q: i64 = if qf.abs() < 8_388_608.0 {
            qf as i64
        } else {
            let qd = (x as f64 * inv_two_eb).round();
            if !qd.is_finite() || qd.abs() > i32::MAX as f64 / 2.0 {
                return None;
            }
            qd as i64
        };
        let d = if lorenzo { q - prev } else { q };
        prev = q;
        symbols.push(zigzag(d as i32));
    }
    Some(symbols)
}

/// Inverse of [`prequant_symbols`] given the decoded symbol stream:
/// accumulate (or take absolute) quantized values and reconstruct.
pub(crate) fn prequant_accumulate(
    base: u32,
    deltas: &[u32],
    lorenzo: bool,
    two_eb_f32: f32,
    out: &mut Vec<f32>,
) {
    let mut q: i64 = unzigzag(base) as i64;
    // f32 reconstruction is exact in the integer part for |q| < 2^24
    // (always true on the packed path) and ~1 ulp otherwise.
    out.push(q as f32 * two_eb_f32);
    for &z in deltas {
        let d = unzigzag(z) as i64;
        q = if lorenzo { q + d } else { d };
        out.push(q as f32 * two_eb_f32);
    }
}

/// Fixed-rate quantization of one block: the block's max-magnitude
/// scale and the signed codes, clamped to ±`qmax`. Exactly the
/// arithmetic of the historical `FixedRate` encoder.
pub(crate) fn fixed_rate_quantize(block: &[f32], qmax: f64) -> (f32, Vec<i32>) {
    let scale = block
        .iter()
        .map(|x| if x.is_finite() { x.abs() } else { 0.0 })
        .fold(0.0f32, f32::max);
    let codes = block
        .iter()
        .map(|&x| {
            let v = if scale > 0.0 && x.is_finite() {
                ((x as f64 / scale as f64) * qmax).round() as i32
            } else {
                0
            };
            v.clamp(-(qmax as i32), qmax as i32)
        })
        .collect();
    (scale, codes)
}

/// Inverse of one [`fixed_rate_quantize`] code.
pub(crate) fn fixed_rate_dequantize(code: i32, qmax: f64, scale: f32) -> f32 {
    (code as f64 / qmax * scale as f64) as f32
}

/// Predictor stage over u32 "levels" (bit patterns or two's-complement
/// codes): zigzagged wrapping deltas, the first absolute.
fn predict_levels<I: IntoIterator<Item = u32>>(levels: I, lorenzo: bool) -> Vec<u32> {
    let mut prev = 0u32;
    levels
        .into_iter()
        .map(|l| {
            let d = if lorenzo { l.wrapping_sub(prev) } else { l };
            prev = l;
            zigzag(d as i32)
        })
        .collect()
}

/// Inverse of [`predict_levels`].
fn unpredict_levels(base: u32, rest: &[u32], lorenzo: bool) -> Vec<u32> {
    let mut out = Vec::with_capacity(rest.len() + 1);
    let mut prev = unzigzag(base) as u32;
    out.push(prev);
    for &s in rest {
        let d = unzigzag(s) as u32;
        let l = if lorenzo { prev.wrapping_add(d) } else { d };
        out.push(l);
        prev = l;
    }
    out
}

// ---------------------------------------------------------------------
// Coder stage (over the non-base symbols of one block).
// ---------------------------------------------------------------------

fn code_bitpack(rest: &[u32], body: &mut Vec<u8>) -> u8 {
    let width = rest.iter().map(|&s| bit_width(s)).max().unwrap_or(0);
    pack_fixed_into(rest, width, body);
    width as u8
}

fn decode_bitpack(
    payload: &[u8],
    cursor: &mut usize,
    width: u32,
    rest: usize,
    out: &mut Vec<u32>,
) -> Result<()> {
    if width > 32 {
        return Err(Error::compress(format!("codec: bad pack width {width}")));
    }
    let buf = payload
        .get(*cursor..)
        .ok_or_else(|| Error::compress("codec: truncated packed block"))?;
    let nbytes = unpack_fixed_into(buf, rest, width, out)
        .ok_or_else(|| Error::compress("codec: truncated packed block"))?;
    *cursor += nbytes;
    Ok(())
}

fn code_byteplane(rest: &[u32], body: &mut Vec<u8>) -> u8 {
    let planes = rest.iter().map(|&s| bit_width(s).div_ceil(8)).max().unwrap_or(0);
    for p in 0..planes {
        for &s in rest {
            body.push((s >> (8 * p)) as u8);
        }
    }
    planes as u8
}

fn decode_byteplane(
    payload: &[u8],
    cursor: &mut usize,
    planes: u32,
    rest: usize,
    out: &mut Vec<u32>,
) -> Result<()> {
    if planes > 4 {
        return Err(Error::compress(format!("codec: bad plane count {planes}")));
    }
    let need = planes as usize * rest;
    let bytes = payload
        .get(*cursor..*cursor + need)
        .ok_or_else(|| Error::compress("codec: truncated byteplane block"))?;
    let start = out.len();
    out.extend(std::iter::repeat(0u32).take(rest));
    for p in 0..planes as usize {
        for (i, slot) in out[start..].iter_mut().enumerate() {
            *slot |= (bytes[p * rest + i] as u32) << (8 * p);
        }
    }
    *cursor += need;
    Ok(())
}

fn rice_put(w: &mut BitWriter, v: u32, k: u32) {
    let q = v >> k;
    if q < RICE_ESCAPE {
        for _ in 0..q {
            w.put(1, 1);
        }
        w.put(0, 1);
        if k > 0 {
            w.put(v & ((1u32 << k) - 1), k);
        }
    } else {
        for _ in 0..RICE_ESCAPE {
            w.put(1, 1);
        }
        w.put(v, 32);
    }
}

fn rice_get(r: &mut BitReader, k: u32) -> Option<u32> {
    let mut q = 0u32;
    while r.get(1)? == 1 {
        q += 1;
        if q == RICE_ESCAPE {
            return r.get(32);
        }
    }
    let low = if k > 0 { r.get(k)? } else { 0 };
    Some((q << k) | low)
}

fn rice_cost(v: u32, k: u32) -> u64 {
    let q = v >> k;
    if q < RICE_ESCAPE {
        (q + 1 + k) as u64
    } else {
        (RICE_ESCAPE + 32) as u64
    }
}

fn best_rice_k(values: &[u32]) -> u32 {
    let mut best = 0u32;
    let mut best_cost = u64::MAX;
    for k in 0..=RICE_K_MAX {
        let cost: u64 = values.iter().map(|&v| rice_cost(v, k)).sum();
        if cost < best_cost {
            best_cost = cost;
            best = k;
        }
    }
    best
}

fn code_rle_rice(rest: &[u32], body: &mut Vec<u8>) -> u8 {
    let nonzero: Vec<u32> = rest.iter().filter(|&&s| s != 0).map(|&s| s - 1).collect();
    let k = best_rice_k(&nonzero);
    let mut w = BitWriter::new();
    let mut i = 0usize;
    while i < rest.len() {
        let mut z = 0usize;
        while i + z < rest.len() && rest[i + z] == 0 {
            z += 1;
        }
        rice_put(&mut w, z as u32, ZRUN_K);
        i += z;
        if i == rest.len() {
            break;
        }
        rice_put(&mut w, rest[i] - 1, k);
        i += 1;
    }
    body.extend_from_slice(&w.finish());
    k as u8
}

fn decode_rle_rice(
    payload: &[u8],
    cursor: &mut usize,
    k: u32,
    rest: usize,
    out: &mut Vec<u32>,
) -> Result<()> {
    if k > RICE_K_MAX {
        return Err(Error::compress(format!("codec: bad rice parameter {k}")));
    }
    let buf = payload
        .get(*cursor..)
        .ok_or_else(|| Error::compress("codec: truncated rice block"))?;
    let mut r = BitReader::new(buf);
    let mut got = 0usize;
    while got < rest {
        let z = rice_get(&mut r, ZRUN_K)
            .ok_or_else(|| Error::compress("codec: truncated rice block"))? as usize;
        if got + z > rest {
            return Err(Error::compress("codec: zero run overflows block"));
        }
        out.extend(std::iter::repeat(0u32).take(z));
        got += z;
        if got == rest {
            break;
        }
        let v = rice_get(&mut r, k)
            .ok_or_else(|| Error::compress("codec: truncated rice block"))?;
        out.push(v.wrapping_add(1));
        got += 1;
    }
    *cursor += r.bit_pos().div_ceil(8);
    Ok(())
}

// ---------------------------------------------------------------------
// The generic staged compressor (GZCX container).
// ---------------------------------------------------------------------

/// A non-canonical stage composition over the self-describing `GZCX`
/// container. Built via [`CodecSpec::build`]; never constructed with an
/// invalid spec/eb pair.
#[derive(Debug, Clone, Copy)]
struct Staged {
    spec: CodecSpec,
    eb: f64,
}

fn raw_block(block: &[f32], out: &mut Vec<u8>) {
    out.push(RAW_BLOCK);
    for &x in block {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl Staged {
    fn encode_block(&self, block: &[f32], out: &mut Vec<u8>) {
        let lorenzo = self.spec.predictor == PredictorKind::Lorenzo1D;
        let (scale, symbols) = match self.spec.quantizer {
            QuantizerKind::Prequant => match prequant_symbols(block, self.eb, lorenzo) {
                Some(s) => (None, s),
                None => return raw_block(block, out),
            },
            QuantizerKind::FixedRate(bits) => {
                let qmax = ((1u64 << (bits - 1)) - 1) as f64;
                let (scale, codes) = fixed_rate_quantize(block, qmax);
                (
                    Some(scale),
                    predict_levels(codes.iter().map(|&v| v as u32), lorenzo),
                )
            }
            QuantizerKind::Lossless => (
                None,
                predict_levels(block.iter().map(|x| x.to_bits()), lorenzo),
            ),
        };
        let mut body = Vec::with_capacity(block.len() * 4);
        write_varint(&mut body, symbols[0]);
        let tag = match self.spec.coder {
            CoderKind::Bitpack => code_bitpack(&symbols[1..], &mut body),
            CoderKind::Byteplane => code_byteplane(&symbols[1..], &mut body),
            CoderKind::RleRice => code_rle_rice(&symbols[1..], &mut body),
        };
        let scale_len = if scale.is_some() { 4 } else { 0 };
        // Incompressible block: verbatim f32 is both smaller and exact.
        if scale_len + body.len() > block.len() * 4 {
            return raw_block(block, out);
        }
        out.push(tag);
        if let Some(s) = scale {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&body);
    }

    fn decode_block(
        &self,
        count: usize,
        payload: &[u8],
        cursor: &mut usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let tag = *payload
            .get(*cursor)
            .ok_or_else(|| Error::compress("codec: truncated block tag"))?;
        *cursor += 1;
        if tag == RAW_BLOCK {
            let need = count * 4;
            let slice = payload
                .get(*cursor..*cursor + need)
                .ok_or_else(|| Error::compress("codec: truncated raw block"))?;
            for ch in slice.chunks_exact(4) {
                out.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            *cursor += need;
            return Ok(());
        }
        let scale = if self.spec.is_fixed_rate() {
            let sb = payload
                .get(*cursor..*cursor + 4)
                .ok_or_else(|| Error::compress("codec: truncated block scale"))?;
            *cursor += 4;
            Some(f32::from_le_bytes(sb.try_into().unwrap()))
        } else {
            None
        };
        let base = read_varint(payload, cursor)
            .ok_or_else(|| Error::compress("codec: truncated block base"))?;
        let rest = count - 1;
        let mut syms: Vec<u32> = Vec::with_capacity(rest);
        match self.spec.coder {
            CoderKind::Bitpack => decode_bitpack(payload, cursor, tag as u32, rest, &mut syms)?,
            CoderKind::Byteplane => decode_byteplane(payload, cursor, tag as u32, rest, &mut syms)?,
            CoderKind::RleRice => decode_rle_rice(payload, cursor, tag as u32, rest, &mut syms)?,
        }
        let lorenzo = self.spec.predictor == PredictorKind::Lorenzo1D;
        match self.spec.quantizer {
            QuantizerKind::Prequant => {
                prequant_accumulate(base, &syms, lorenzo, (2.0 * self.eb) as f32, out)
            }
            QuantizerKind::Lossless => {
                for l in unpredict_levels(base, &syms, lorenzo) {
                    out.push(f32::from_bits(l));
                }
            }
            QuantizerKind::FixedRate(bits) => {
                let qmax = ((1u64 << (bits - 1)) - 1) as f64;
                let scale = scale.unwrap_or(0.0);
                for l in unpredict_levels(base, &syms, lorenzo) {
                    out.push(fixed_rate_dequantize(l as i32, qmax, scale));
                }
            }
        }
        Ok(())
    }
}

fn spec_bytes(spec: CodecSpec) -> [u8; 4] {
    let p = match spec.predictor {
        PredictorKind::None => 0,
        PredictorKind::Lorenzo1D => 1,
    };
    let (q, qb) = match spec.quantizer {
        QuantizerKind::Prequant => (0, 0),
        QuantizerKind::Lossless => (1, 0),
        QuantizerKind::FixedRate(b) => (2, b),
    };
    let c = match spec.coder {
        CoderKind::Bitpack => 0,
        CoderKind::Byteplane => 1,
        CoderKind::RleRice => 2,
    };
    [p, q, qb, c]
}

/// Decode a `GZCX` stream from its self-describing header alone.
pub(crate) fn decode_staged(stream: &[u8]) -> Result<Vec<f32>> {
    if stream.len() < HEADER || stream[0..4] != MAGIC {
        return Err(Error::compress("codec: bad magic / truncated header"));
    }
    if stream[4] != VERSION {
        return Err(Error::compress(format!("codec: unknown version {}", stream[4])));
    }
    let predictor = match stream[5] {
        0 => PredictorKind::None,
        1 => PredictorKind::Lorenzo1D,
        other => return Err(Error::compress(format!("codec: bad predictor {other}"))),
    };
    let quantizer = match stream[6] {
        0 => QuantizerKind::Prequant,
        1 => QuantizerKind::Lossless,
        2 => {
            let bits = stream[7];
            if !(2..=28).contains(&bits) {
                return Err(Error::compress(format!("codec: bad rate {bits}")));
            }
            QuantizerKind::FixedRate(bits)
        }
        other => return Err(Error::compress(format!("codec: bad quantizer {other}"))),
    };
    let coder = match stream[8] {
        0 => CoderKind::Bitpack,
        1 => CoderKind::Byteplane,
        2 => CoderKind::RleRice,
        other => return Err(Error::compress(format!("codec: bad coder {other}"))),
    };
    let eb = f64::from_le_bytes(stream[9..17].try_into().unwrap());
    if quantizer == QuantizerKind::Prequant && !(eb > 0.0 && eb.is_finite()) {
        return Err(Error::compress("codec: bad stream bound"));
    }
    let n = u64::from_le_bytes(stream[17..25].try_into().unwrap()) as usize;
    let st = Staged {
        spec: CodecSpec {
            predictor,
            quantizer,
            coder,
        },
        eb,
    };
    let payload = &stream[HEADER..];
    let mut out = Vec::with_capacity(n);
    let mut cursor = 0usize;
    let mut remaining = n;
    while remaining > 0 {
        let count = remaining.min(BLOCK);
        st.decode_block(count, payload, &mut cursor, &mut out)?;
        remaining -= count;
    }
    Ok(out)
}

impl Compressor for Staged {
    fn name(&self) -> &'static str {
        if self.spec == CodecSpec::lossless() {
            "lossless(lorenzo+byteplane)"
        } else if self.spec == CodecSpec::rle_rice() {
            "cuszp-like(rle+rice)"
        } else {
            "staged-codec"
        }
    }

    fn compress(&self, data: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER + data.len() * 2 + 64);
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&spec_bytes(self.spec));
        out.extend_from_slice(&self.eb.to_le_bytes());
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for block in data.chunks(BLOCK) {
            self.encode_block(block, &mut out);
        }
        out
    }

    fn decompress(&self, stream: &[u8]) -> Result<Vec<f32>> {
        // Streams are fully self-describing (spec + eb in the header).
        decode_staged(stream)
    }

    fn is_error_bounded(&self) -> bool {
        self.spec.is_error_bounded()
    }

    fn error_bound(&self) -> Option<f64> {
        match self.spec.quantizer {
            QuantizerKind::Prequant => Some(self.eb),
            QuantizerKind::Lossless => Some(0.0),
            QuantizerKind::FixedRate(_) => None,
        }
    }

    fn fixed_output_size(&self, _n: usize) -> Option<usize> {
        None
    }

    fn rebound(&self, eb: f64) -> Option<Arc<dyn Compressor>> {
        match self.spec.quantizer {
            QuantizerKind::Prequant => {
                if eb > 0.0 && eb.is_finite() {
                    Some(Arc::new(Staged {
                        spec: self.spec,
                        eb,
                    }))
                } else {
                    None
                }
            }
            // Zero distortion complies with any requested bound.
            QuantizerKind::Lossless => Some(Arc::new(*self)),
            // No per-call bound exists to rebind.
            QuantizerKind::FixedRate(_) => None,
        }
    }

    fn spec(&self) -> Option<CodecSpec> {
        Some(self.spec)
    }
}

/// Decode any stream produced by the built-in codecs, dispatching on
/// the stream magic (`GZCP`, `GZFR`, `GZCX`) — what lets one rank
/// decode a neighbor's payload even when the two legs (or the two
/// ranks' ambient configs) bind different codecs.
pub fn decode_any(stream: &[u8]) -> Result<Vec<f32>> {
    match stream.get(0..4) {
        Some(m) if m == b"GZCP" => {
            if stream.len() < 13 {
                return Err(Error::compress("truncated cuszp header"));
            }
            let eb = f64::from_le_bytes(stream[5..13].try_into().unwrap());
            if !(eb > 0.0 && eb.is_finite()) {
                return Err(Error::compress("bad cuszp stream bound"));
            }
            CuszpLike::new(eb).decompress(stream)
        }
        Some(m) if m == b"GZFR" => FixedRate::new(8).decompress(stream),
        Some(m) if m == b"GZCX" => decode_staged(stream),
        _ => Err(Error::compress("unrecognized compressed stream magic")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{max_abs_diff, Pcg32};

    fn smooth(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.003).sin() * 2.0).collect()
    }

    #[test]
    fn canonical_builds_map_to_historical_formats() {
        let c = CodecSpec::cuszp().build(1e-3).unwrap();
        assert_eq!(c.name(), "cuszp-like(eb)");
        assert_eq!(c.spec(), Some(CodecSpec::cuszp()));
        let f = CodecSpec::fixed_rate(8).build(0.0).unwrap();
        assert_eq!(f.name(), "fixed-rate(zfp1d-like)");
        assert_eq!(f.spec(), Some(CodecSpec::fixed_rate(8)));
        let l = CodecSpec::lossless().build(0.0).unwrap();
        assert_eq!(l.error_bound(), Some(0.0));
        assert!(l.is_error_bounded());
        let r = CodecSpec::rle_rice().build(1e-3).unwrap();
        assert_eq!(r.error_bound(), Some(1e-3));
        // Unbuildable: prequant without a usable bound, silly rates.
        assert!(CodecSpec::cuszp().build(0.0).is_none());
        assert!(CodecSpec::rle_rice().build(f64::NAN).is_none());
        assert!(CodecSpec::fixed_rate(1).build(0.0).is_none());
        assert!(CodecSpec::fixed_rate(29).build(0.0).is_none());
    }

    #[test]
    fn labels_parse_back_for_every_composition() {
        for spec in CodecSpec::compositions(8) {
            let label = spec.label();
            assert_eq!(CodecSpec::parse(&label), Some(spec), "{label}");
        }
        assert_eq!(CodecSpec::parse("cuszp"), Some(CodecSpec::cuszp()));
        assert_eq!(CodecSpec::parse("lossless"), Some(CodecSpec::lossless()));
        assert_eq!(CodecSpec::parse("rle-rice"), Some(CodecSpec::rle_rice()));
        assert_eq!(CodecSpec::parse("fixed12"), Some(CodecSpec::fixed_rate(12)));
        assert_eq!(
            CodecSpec::parse("lorenzo+prequant+rice"),
            Some(CodecSpec::rle_rice())
        );
        assert!(CodecSpec::parse("fixed99").is_none());
        assert!(CodecSpec::parse("huffman").is_none());
        assert!(CodecSpec::parse("none+prequant").is_none());
    }

    #[test]
    fn lossless_round_trip_is_bit_exact() {
        let mut rng = Pcg32::seeded(11);
        let mut data = rng.uniform_vec(5000, -100.0, 100.0);
        data.push(f32::NAN);
        data.push(-0.0);
        data.push(f32::INFINITY);
        let c = CodecSpec::lossless().build(0.0).unwrap();
        let back = c.decompress(&c.compress(&data)).unwrap();
        assert_eq!(back.len(), data.len());
        for (a, b) in back.iter().zip(data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn lossless_compresses_smooth_data() {
        let data = smooth(100_000);
        let c = CodecSpec::lossless().build(0.0).unwrap();
        let stream = c.compress(&data);
        let r = super::super::ratio(data.len() * 4, stream.len());
        assert!(r > 1.2, "lossless ratio {r}");
    }

    #[test]
    fn rle_rice_bounded_and_denser_than_bitpack() {
        let data = smooth(100_000);
        let rice = CodecSpec::rle_rice().build(1e-3).unwrap();
        let stream = rice.compress(&data);
        let back = rice.decompress(&stream).unwrap();
        assert!(max_abs_diff(&back, &data) <= 1e-3 + 1e-6);
        let bitpack = CodecSpec::cuszp().build(1e-3).unwrap().compress(&data);
        assert!(
            stream.len() < bitpack.len(),
            "rice {} vs bitpack {}",
            stream.len(),
            bitpack.len()
        );
    }

    #[test]
    fn staged_raw_fallback_is_lossless() {
        let spec = CodecSpec::rle_rice();
        let c = spec.build(1e-9).unwrap();
        let data = vec![1e30f32, -1e30, 5e29, 0.0];
        assert_eq!(c.decompress(&c.compress(&data)).unwrap(), data);
    }

    #[test]
    fn decode_any_dispatches_on_magic() {
        let data = smooth(1000);
        for spec in [
            CodecSpec::cuszp(),
            CodecSpec::rle_rice(),
            CodecSpec::lossless(),
        ] {
            let c = spec.build(1e-3).unwrap();
            let back = decode_any(&c.compress(&data)).unwrap();
            assert!(max_abs_diff(&back, &data) <= 1e-3 + 1e-6, "{}", spec.label());
        }
        let f = CodecSpec::fixed_rate(12).build(0.0).unwrap();
        let back = decode_any(&f.compress(&data)).unwrap();
        assert_eq!(back.len(), data.len());
        assert!(decode_any(b"XXXXsomething").is_err());
        assert!(decode_any(&[]).is_err());
    }

    #[test]
    fn staged_rebound_follows_the_quantizer_family() {
        let rice = CodecSpec::rle_rice().build(1e-4).unwrap();
        let loose = rice.rebound(1e-2).expect("prequant family rebinds");
        assert_eq!(loose.error_bound(), Some(1e-2));
        assert_eq!(loose.spec(), Some(CodecSpec::rle_rice()));
        assert!(rice.rebound(0.0).is_none());
        let lossless = CodecSpec::lossless().build(0.0).unwrap();
        let rebound = lossless.rebound(1e-3).expect("zero distortion complies");
        assert_eq!(rebound.error_bound(), Some(0.0));
        let fr = CodecSpec {
            predictor: PredictorKind::Lorenzo1D,
            quantizer: QuantizerKind::FixedRate(8),
            coder: CoderKind::RleRice,
        }
        .build(0.0)
        .unwrap();
        assert!(fr.rebound(1e-3).is_none());
    }

    #[test]
    fn every_composition_round_trips() {
        let mut rng = Pcg32::seeded(23);
        let data = rng.uniform_vec(1000, -5.0, 5.0);
        for spec in CodecSpec::compositions(12) {
            let c = spec.build(1e-3).unwrap();
            let stream = c.compress(&data);
            let back = c.decompress(&stream).unwrap();
            assert_eq!(back.len(), data.len(), "{}", spec.label());
            match spec.quantizer {
                QuantizerKind::Prequant => {
                    assert!(
                        max_abs_diff(&back, &data) <= 1e-3 + 1e-6,
                        "{}",
                        spec.label()
                    );
                }
                QuantizerKind::Lossless => {
                    for (a, b) in back.iter().zip(data.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{}", spec.label());
                    }
                }
                QuantizerKind::FixedRate(_) => {
                    // Per-block relative bound: |x| ≤ 5 here.
                    assert!(
                        max_abs_diff(&back, &data) <= 5.0 / 2047.0 + 1e-5,
                        "{}",
                        spec.label()
                    );
                }
            }
        }
    }
}
