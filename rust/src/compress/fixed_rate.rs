//! Fixed-rate (1D-ZFP-class) compressor — the CPRP2P baseline.
//!
//! Per 32-value block: store the block's max-magnitude as an f32 scale,
//! then every value as a signed fixed-point fraction of that scale at a
//! fixed `rate` bits. The output size is *exactly known* from the input
//! length (the property prior work [30, 31] exploits to pre-post
//! receives), but the pointwise error is `≈ blockmax / 2^(rate−1)` —
//! proportional to local magnitude, i.e. **unbounded** in absolute
//! terms. The paper's accuracy-aware design rejects exactly this
//! trade-off; we implement it to reproduce the CPRP2P comparisons.

use crate::error::{Error, Result};

use super::bitpack::{pack_fixed, unpack_fixed, unzigzag, zigzag};
use super::codec::{fixed_rate_dequantize, fixed_rate_quantize, CodecSpec};
use super::Compressor;

/// Values per block.
pub const BLOCK: usize = 32;

/// Stream magic: "GZFR".
const MAGIC: [u8; 4] = *b"GZFR";
/// Header: magic(4) + rate(1) + count(8).
const HEADER: usize = 13;

/// Fixed-rate compressor at `rate` bits per value (2..=28).
#[derive(Debug, Clone, Copy)]
pub struct FixedRate {
    rate: u32,
}

impl FixedRate {
    /// Construct with `rate` bits per value.
    pub fn new(rate: u32) -> Self {
        assert!((2..=28).contains(&rate), "rate must be in 2..=28");
        FixedRate { rate }
    }

    /// Bits per value.
    pub fn rate(&self) -> u32 {
        self.rate
    }

    fn block_bytes(&self, count: usize) -> usize {
        4 + (count * self.rate as usize).div_ceil(8)
    }
}

impl Compressor for FixedRate {
    fn name(&self) -> &'static str {
        "fixed-rate(zfp1d-like)"
    }

    fn compress(&self, data: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.fixed_output_size(data.len()).unwrap());
        out.extend_from_slice(&MAGIC);
        out.push(self.rate as u8);
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        // Max representable quantized magnitude.
        let qmax = ((1u64 << (self.rate - 1)) - 1) as f64;
        for block in data.chunks(BLOCK) {
            // The quantizer stage is shared with the staged pipeline
            // (this struct is the canonical `{None, FixedRate, Bitpack}`
            // composition — see [`CodecSpec::fixed_rate`]).
            let (scale, codes) = fixed_rate_quantize(block, qmax);
            out.extend_from_slice(&scale.to_le_bytes());
            let zz: Vec<u32> = codes.iter().map(|&v| zigzag(v)).collect();
            out.extend_from_slice(&pack_fixed(&zz, self.rate));
        }
        out
    }

    fn decompress(&self, stream: &[u8]) -> Result<Vec<f32>> {
        if stream.len() < HEADER || stream[0..4] != MAGIC {
            return Err(Error::compress("fixed-rate: bad magic"));
        }
        let rate = stream[4] as u32;
        if !(2..=28).contains(&rate) {
            return Err(Error::compress("fixed-rate: bad rate"));
        }
        let n = u64::from_le_bytes(stream[5..13].try_into().unwrap()) as usize;
        let qmax = ((1u64 << (rate - 1)) - 1) as f64;
        let mut out = Vec::with_capacity(n);
        let mut cursor = HEADER;
        let mut remaining = n;
        while remaining > 0 {
            let count = remaining.min(BLOCK);
            let scale_bytes = stream
                .get(cursor..cursor + 4)
                .ok_or_else(|| Error::compress("fixed-rate: truncated scale"))?;
            let scale = f32::from_le_bytes(scale_bytes.try_into().unwrap());
            cursor += 4;
            let nbytes = (count * rate as usize).div_ceil(8);
            let packed = stream
                .get(cursor..cursor + nbytes)
                .ok_or_else(|| Error::compress("fixed-rate: truncated block"))?;
            cursor += nbytes;
            let codes = unpack_fixed(packed, count, rate)
                .ok_or_else(|| Error::compress("fixed-rate: bit underrun"))?;
            for z in codes {
                out.push(fixed_rate_dequantize(unzigzag(z), qmax, scale));
            }
            remaining -= count;
        }
        Ok(out)
    }

    fn is_error_bounded(&self) -> bool {
        false
    }

    fn error_bound(&self) -> Option<f64> {
        None
    }

    fn fixed_output_size(&self, n: usize) -> Option<usize> {
        let full = n / BLOCK;
        let rem = n % BLOCK;
        let mut size = HEADER + full * self.block_bytes(BLOCK);
        if rem > 0 {
            size += self.block_bytes(rem);
        }
        Some(size)
    }

    fn spec(&self) -> Option<CodecSpec> {
        Some(CodecSpec::fixed_rate(self.rate as u8))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, max_abs_diff, Cases, Pcg32};

    #[test]
    fn output_size_is_exactly_predicted() {
        let c = FixedRate::new(8);
        for n in [0usize, 1, 31, 32, 33, 1000, 4096] {
            let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let stream = c.compress(&data);
            assert_eq!(stream.len(), c.fixed_output_size(n).unwrap(), "n={n}");
        }
    }

    #[test]
    fn round_trip_relative_error() {
        let mut rng = Pcg32::seeded(5);
        let data = rng.uniform_vec(2048, -1.0, 1.0);
        let c = FixedRate::new(12);
        let back = c.decompress(&c.compress(&data)).unwrap();
        // Error ≤ blockmax / 2^(rate-1); blockmax ≤ 1 here.
        assert!(max_abs_diff(&back, &data) <= 1.0 / 2048.0 + 1e-6);
    }

    #[test]
    fn error_scales_with_magnitude_unbounded() {
        // The accuracy hazard: same rate, 1e6× the magnitude → ~1e6×
        // the absolute error. An error-bounded compressor would keep
        // absolute error fixed.
        let mut rng = Pcg32::seeded(6);
        let small = rng.uniform_vec(1024, -1.0, 1.0);
        let big: Vec<f32> = small.iter().map(|x| x * 1e6).collect();
        let c = FixedRate::new(8);
        let e_small = max_abs_diff(&c.decompress(&c.compress(&small)).unwrap(), &small);
        let e_big = max_abs_diff(&c.decompress(&c.compress(&big)).unwrap(), &big);
        assert!(e_big > 1e4 * e_small, "e_small={e_small} e_big={e_big}");
    }

    #[test]
    fn compression_ratio_is_fixed() {
        let c = FixedRate::new(8);
        let n = 32 * 1000;
        let size = c.fixed_output_size(n).unwrap();
        // 32 f32 (128 B) → 4 + 32 B = 36 B per block ⇒ ratio ≈ 3.56.
        let r = super::super::ratio(n * 4, size);
        assert!((3.0..4.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn zero_and_constant_blocks() {
        let c = FixedRate::new(8);
        let zeros = vec![0.0f32; 100];
        assert_eq!(c.decompress(&c.compress(&zeros)).unwrap(), zeros);
        let konst = vec![7.5f32; 64];
        let back = c.decompress(&c.compress(&konst)).unwrap();
        assert!(max_abs_diff(&back, &konst) <= 7.5 / 127.0 + 1e-6);
    }

    #[test]
    fn corrupt_stream_rejected() {
        let c = FixedRate::new(8);
        assert!(c.decompress(b"xx").is_err());
        let mut s = c.compress(&[1.0f32; 40]);
        s.truncate(s.len() - 2);
        assert!(c.decompress(&s).is_err());
    }

    #[test]
    fn not_error_bounded_reported() {
        let c = FixedRate::new(8);
        assert!(!c.is_error_bounded());
        assert!(c.error_bound().is_none());
        assert!(c.fixed_output_size(100).is_some());
    }

    #[test]
    fn prop_round_trip_and_size() {
        forall(
            Cases::n(40),
            |rng| {
                let n = rng.range_usize(0, 500);
                let rate = *rng.choose(&[4u32, 8, 12, 16]);
                let scale = rng.range_f32(0.01, 1000.0);
                let data: Vec<f32> =
                    (0..n).map(|_| rng.next_gaussian() * scale).collect();
                (rate, data)
            },
            |(rate, data)| {
                let c = FixedRate::new(*rate);
                let stream = c.compress(data);
                if stream.len() != c.fixed_output_size(data.len()).unwrap() {
                    return Err("size prediction wrong".into());
                }
                let back = c.decompress(&stream).map_err(|e| e.to_string())?;
                if back.len() != data.len() {
                    return Err("length mismatch".into());
                }
                // Per-block relative bound.
                for (blk, (orig, rec)) in data
                    .chunks(BLOCK)
                    .zip(back.chunks(BLOCK))
                    .enumerate()
                {
                    let bmax = orig.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
                    let tol = bmax / ((1u64 << (rate - 1)) - 1) as f32 + 1e-6;
                    for (a, b) in orig.iter().zip(rec.iter()) {
                        if (a - b).abs() > tol {
                            return Err(format!("block {blk}: |{a}-{b}| > {tol}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
