//! Bit-packing utilities: fixed-width integer packing and zigzag coding.
//!
//! cuSZp's encode stage stores, per block, the maximum significant bit
//! width of the (zigzagged) quantization deltas and then packs every
//! delta at exactly that width. These helpers implement that layout.

/// Zigzag-encode a signed 32-bit integer into an unsigned one
/// (0, -1, 1, -2, 2 → 0, 1, 2, 3, 4) so small-magnitude values have
/// small unsigned representations.
#[inline]
pub fn zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u32) -> i32 {
    ((v >> 1) as i32) ^ -((v & 1) as i32)
}

/// Number of bits needed to represent `v` (0 needs 0 bits).
#[inline]
pub fn bit_width(v: u32) -> u32 {
    32 - v.leading_zeros()
}

/// A little-endian bit writer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the last byte (0..8).
    used: u32,
}

impl BitWriter {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `width` bits of `v`.
    pub fn put(&mut self, v: u32, width: u32) {
        debug_assert!(width <= 32);
        debug_assert!(width == 32 || v < (1u64 << width) as u32);
        let mut remaining = width;
        let mut val = v as u64;
        while remaining > 0 {
            if self.used == 0 {
                self.buf.push(0);
            }
            let space = 8 - self.used;
            let take = remaining.min(space);
            let last = self.buf.last_mut().unwrap();
            *last |= ((val & ((1u64 << take) - 1)) as u8) << self.used;
            val >>= take;
            self.used = (self.used + take) % 8;
            remaining -= take;
        }
    }

    /// Finish, returning the packed bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far (including the partial last byte).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// A little-endian bit reader over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Absolute bit cursor.
    pos: usize,
}

impl<'a> BitReader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `width` bits (little-endian), or `None` past the end.
    pub fn get(&mut self, width: u32) -> Option<u32> {
        debug_assert!(width <= 32);
        if width == 0 {
            return Some(0);
        }
        if self.pos + width as usize > self.buf.len() * 8 {
            return None;
        }
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < width {
            let byte = self.buf[self.pos / 8] as u64;
            let off = (self.pos % 8) as u32;
            let avail = 8 - off;
            let take = (width - got).min(avail);
            let bits = (byte >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            self.pos += take as usize;
        }
        Some(out as u32)
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Advance the cursor to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.pos = self.pos.div_ceil(8) * 8;
    }
}

/// LEB128 varint write (used for per-block absolute bases).
pub fn write_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// LEB128 varint read; advances `cursor`.
pub fn read_varint(buf: &[u8], cursor: &mut usize) -> Option<u32> {
    let mut v: u32 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *buf.get(*cursor)?;
        *cursor += 1;
        v |= ((byte & 0x7F) as u32) << shift;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
        if shift >= 35 {
            return None;
        }
    }
}

/// Pack `values` at fixed `width` bits each. `width == 0` packs nothing.
pub fn pack_fixed(values: &[u32], width: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity((values.len() * width as usize).div_ceil(8));
    pack_fixed_into(values, width, &mut out);
    out
}

/// Append `values` packed at fixed `width` bits (≤ 32) to `out`,
/// starting at a byte boundary. Hot path of the cuSZp-like encoder: a
/// u64 shift-accumulator instead of per-bit bookkeeping.
pub fn pack_fixed_into(values: &[u32], width: u32, out: &mut Vec<u8>) {
    debug_assert!(width <= 32);
    if width == 0 {
        return;
    }
    out.reserve((values.len() * width as usize).div_ceil(8));
    let mut acc: u64 = 0;
    let mut bits: u32 = 0;
    for &v in values {
        debug_assert!(width == 32 || (v as u64) < (1u64 << width));
        acc |= (v as u64) << bits;
        bits += width;
        while bits >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            bits -= 8;
        }
    }
    if bits > 0 {
        out.push(acc as u8);
    }
}

/// Unpack `count` values of `width` bits from `buf` into `out`,
/// returning the number of bytes consumed, or `None` on underrun.
/// Accumulator-based hot path of the decoder.
pub fn unpack_fixed_into(
    buf: &[u8],
    count: usize,
    width: u32,
    out: &mut Vec<u32>,
) -> Option<usize> {
    debug_assert!(width <= 32);
    if width == 0 {
        out.extend(std::iter::repeat(0).take(count));
        return Some(0);
    }
    let nbytes = (count * width as usize).div_ceil(8);
    if buf.len() < nbytes {
        return None;
    }
    out.reserve(count);
    let mask: u64 = if width == 32 { u64::MAX >> 32 } else { (1u64 << width) - 1 };
    let mut acc: u64 = 0;
    let mut bits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..count {
        while bits < width {
            acc |= (buf[pos] as u64) << bits;
            pos += 1;
            bits += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= width;
        bits -= width;
    }
    Some(nbytes)
}

/// Unpack `count` values of `width` bits each from `buf`.
pub fn unpack_fixed(buf: &[u8], count: usize, width: u32) -> Option<Vec<u32>> {
    let mut r = BitReader::new(buf);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(r.get(width)?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Cases};

    #[test]
    fn zigzag_round_trips() {
        for v in [-1000, -2, -1, 0, 1, 2, 1000, i32::MIN / 2, i32::MAX / 2] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn zigzag_orders_by_magnitude() {
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }

    #[test]
    fn bit_width_basics() {
        assert_eq!(bit_width(0), 0);
        assert_eq!(bit_width(1), 1);
        assert_eq!(bit_width(2), 2);
        assert_eq!(bit_width(255), 8);
        assert_eq!(bit_width(256), 9);
        assert_eq!(bit_width(u32::MAX), 32);
    }

    #[test]
    fn writer_reader_round_trip_mixed_widths() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFFFF, 16);
        w.put(0, 0);
        w.put(1, 1);
        w.put(0xDEADBEEF, 32);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3), Some(0b101));
        assert_eq!(r.get(16), Some(0xFFFF));
        assert_eq!(r.get(0), Some(0));
        assert_eq!(r.get(1), Some(1));
        assert_eq!(r.get(32), Some(0xDEADBEEF));
    }

    #[test]
    fn reader_detects_overrun() {
        let bytes = vec![0xAB];
        let mut r = BitReader::new(&bytes);
        assert!(r.get(8).is_some());
        assert!(r.get(1).is_none());
    }

    #[test]
    fn pack_unpack_fixed_round_trip() {
        let vals: Vec<u32> = (0..100).map(|i| i % 13).collect();
        let packed = pack_fixed(&vals, 4);
        assert_eq!(packed.len(), 50);
        let back = unpack_fixed(&packed, 100, 4).unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn pack_width_zero_is_empty() {
        let vals = vec![0u32; 64];
        assert!(pack_fixed(&vals, 0).is_empty());
        assert_eq!(unpack_fixed(&[], 64, 0).unwrap(), vals);
    }

    #[test]
    fn align_byte_skips_to_boundary() {
        let bytes = vec![0xFF, 0x01];
        let mut r = BitReader::new(&bytes);
        r.get(3);
        r.align_byte();
        assert_eq!(r.bit_pos(), 8);
        assert_eq!(r.get(8), Some(0x01));
    }

    #[test]
    fn prop_pack_round_trip_random() {
        forall(
            Cases::n(50),
            |rng| {
                let width = rng.range_u64(0, 32) as u32;
                let n = rng.range_usize(0, 200);
                let vals: Vec<u32> = (0..n)
                    .map(|_| {
                        if width == 0 {
                            0
                        } else if width == 32 {
                            rng.next_u32()
                        } else {
                            rng.next_u32() & ((1u32 << width) - 1)
                        }
                    })
                    .collect();
                (width, vals)
            },
            |(width, vals)| {
                let packed = pack_fixed(vals, *width);
                let back = unpack_fixed(&packed, vals.len(), *width)
                    .ok_or("unpack failed".to_string())?;
                if &back == vals {
                    Ok(())
                } else {
                    Err("round trip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn varint_round_trips_and_detects_truncation() {
        for v in [0u32, 1, 127, 128, 300, 1 << 20, u32::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let mut cursor = 0usize;
            assert_eq!(read_varint(&buf, &mut cursor), Some(v));
            assert_eq!(cursor, buf.len());
        }
        let mut buf = Vec::new();
        write_varint(&mut buf, u32::MAX);
        buf.truncate(buf.len() - 1);
        let mut cursor = 0usize;
        assert!(read_varint(&buf, &mut cursor).is_none());
    }

    #[test]
    fn prop_zigzag_round_trip_random() {
        forall(
            Cases::n(100),
            |rng| rng.next_u32() as i32,
            |v| {
                if unzigzag(zigzag(*v)) == *v {
                    Ok(())
                } else {
                    Err(format!("zigzag broke {v}"))
                }
            },
        );
    }
}
