//! Compression-size profiles for modeled (virtual-payload) runs.
//!
//! The large-scale sweeps (512 GPUs × 646 MB — Figs. 10/12) cannot hold
//! real per-rank payloads in host memory. For those runs the coordinator
//! uses *virtual* buffers whose compressed sizes come from a
//! [`CompressionProfile`]: a ratio curve measured by actually running
//! the real compressor over a sample of the target dataset. The
//! algorithms, schedules and cost models are identical to real runs;
//! only the payload bytes are elided.

use super::Compressor;

/// Measured compressed-size predictor.
#[derive(Debug, Clone)]
pub struct CompressionProfile {
    /// Compressor name this profile was measured with.
    pub compressor: String,
    /// Bytes of stream header+tables per compression call (size floor).
    pub overhead_bytes: usize,
    /// Average payload ratio (raw bytes / (stream bytes − overhead)).
    pub ratio: f64,
}

impl CompressionProfile {
    /// A profile with an explicit ratio (for tests and what-if sweeps).
    pub fn fixed(ratio: f64) -> Self {
        assert!(ratio > 0.0);
        CompressionProfile {
            compressor: "fixed".into(),
            overhead_bytes: 32,
            ratio,
        }
    }

    /// Measure a profile by compressing `sample` with `c`.
    ///
    /// The sample should be drawn from the same dataset the modeled run
    /// will sweep; cuSZp-class ratios are data-dependent.
    pub fn measure(c: &dyn Compressor, sample: &[f32]) -> Self {
        assert!(!sample.is_empty(), "cannot profile an empty sample");
        let stream = c.compress(sample);
        let raw = sample.len() * 4;
        // Estimate the per-call overhead from a tiny compression.
        let overhead = c.compress(&sample[..1.min(sample.len())]).len();
        let payload = stream.len().saturating_sub(overhead).max(1);
        CompressionProfile {
            compressor: c.name().into(),
            overhead_bytes: overhead,
            ratio: raw as f64 / payload as f64,
        }
    }

    /// Predicted compressed size for `raw_bytes` of input.
    pub fn compressed_size(&self, raw_bytes: usize) -> usize {
        if raw_bytes == 0 {
            return self.overhead_bytes;
        }
        self.overhead_bytes + (raw_bytes as f64 / self.ratio).ceil() as usize
    }

    /// Effective end-to-end ratio at `raw_bytes`.
    pub fn effective_ratio(&self, raw_bytes: usize) -> f64 {
        raw_bytes as f64 / self.compressed_size(raw_bytes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::CuszpLike;
    use super::*;
    use crate::testkit::Pcg32;

    #[test]
    fn fixed_profile_sizes() {
        let p = CompressionProfile::fixed(10.0);
        assert_eq!(p.compressed_size(1000), 32 + 100);
        assert_eq!(p.compressed_size(0), 32);
    }

    #[test]
    fn measured_profile_matches_real_compression() {
        // Smooth signal: profile prediction should land within 2× of a
        // real compression of a different slice of the same data.
        let mut rng = Pcg32::seeded(21);
        let mut data = vec![0.0f32; 200_000];
        let mut acc = 0.0f32;
        for x in data.iter_mut() {
            acc += rng.next_gaussian() * 0.001;
            *x = acc;
        }
        let c = CuszpLike::new(1e-4);
        let profile = CompressionProfile::measure(&c, &data[..100_000]);
        let real = c.compress(&data[100_000..]).len();
        let predicted = profile.compressed_size(100_000 * 4);
        let err = (predicted as f64 / real as f64 - 1.0).abs();
        assert!(err < 1.0, "prediction off by {err}: {predicted} vs {real}");
    }

    #[test]
    fn effective_ratio_grows_with_size() {
        let p = CompressionProfile::fixed(50.0);
        assert!(p.effective_ratio(1 << 20) > p.effective_ratio(1 << 10));
    }
}
