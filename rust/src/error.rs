//! Error types for the gZCCL framework.
//!
//! Hand-rolled `Display`/`Error` impls (the offline build has no
//! `thiserror`); the formatting contract matches what the rest of the
//! crate and its tests expect: `"<category> error: <message>"`.

use std::fmt;

/// Unified error type for all gZCCL subsystems.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / value errors.
    Config(String),

    /// Compressor failures (corrupt stream, bound violation, ...).
    Compress(String),

    /// Collective algorithm errors (bad rank layout, mismatched sizes, ...).
    Collective(String),

    /// Coordinator / rank-runtime errors (channel breakage, panics).
    Coordinator(String),

    /// Runtime errors (artifact missing, execution failures).
    Runtime(String),

    /// Metric computation errors (NaN inputs, length mismatch,
    /// zero-range reference).
    Metrics(String),

    /// Accuracy-budget rejections: the planner or the dispatch-time
    /// budget check refused an algorithm/compressor whose worst-case
    /// error cannot certify the requested target. Distinct from
    /// [`Error::Collective`] so callers can tell an *intentional*
    /// rejection from a genuine failure.
    Budget(String),

    /// I/O errors (artifact files, dataset dumps).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Compress(m) => write!(f, "compression error: {m}"),
            Error::Collective(m) => write!(f, "collective error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Metrics(m) => write!(f, "metrics error: {m}"),
            Error::Budget(m) => write!(f, "accuracy-budget error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand constructor for compression errors.
    pub fn compress(msg: impl Into<String>) -> Self {
        Error::Compress(msg.into())
    }
    /// Shorthand constructor for collective errors.
    pub fn collective(msg: impl Into<String>) -> Self {
        Error::Collective(msg.into())
    }
    /// Shorthand constructor for coordinator errors.
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Shorthand constructor for metrics errors.
    pub fn metrics(msg: impl Into<String>) -> Self {
        Error::Metrics(msg.into())
    }
    /// Shorthand constructor for accuracy-budget rejections.
    pub fn budget(msg: impl Into<String>) -> Self {
        Error::Budget(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        let e = Error::config("missing key");
        assert_eq!(e.to_string(), "config error: missing key");
        let e = Error::compress("bad magic");
        assert!(e.to_string().contains("compression"));
        let e = Error::budget("ring over budget");
        assert!(e.to_string().starts_with("accuracy-budget error:"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().starts_with("io error:"));
    }
}
