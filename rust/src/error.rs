//! Error types for the gZCCL framework.

use thiserror::Error;

/// Unified error type for all gZCCL subsystems.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration file / value errors.
    #[error("config error: {0}")]
    Config(String),

    /// Compressor failures (corrupt stream, bound violation, ...).
    #[error("compression error: {0}")]
    Compress(String),

    /// Collective algorithm errors (bad rank layout, mismatched sizes, ...).
    #[error("collective error: {0}")]
    Collective(String),

    /// Coordinator / rank-runtime errors (channel breakage, panics).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// PJRT runtime errors (artifact missing, compile/execute failures).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// I/O errors (artifact files, dataset dumps).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand constructor for compression errors.
    pub fn compress(msg: impl Into<String>) -> Self {
        Error::Compress(msg.into())
    }
    /// Shorthand constructor for collective errors.
    pub fn collective(msg: impl Into<String>) -> Self {
        Error::Collective(msg.into())
    }
    /// Shorthand constructor for coordinator errors.
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        let e = Error::config("missing key");
        assert_eq!(e.to_string(), "config error: missing key");
        let e = Error::compress("bad magic");
        assert!(e.to_string().contains("compression"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
