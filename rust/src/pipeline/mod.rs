//! Non-blocking pipelined collectives: depth selection, `icollective`
//! handles, and persistent plans.
//!
//! gZCCL's Fig. 2 diagnosis is that compression kernels and transfers
//! serialize: while chunk `k` of a hierarchical schedule crosses the
//! internode fabric, the GPU that produced it sits idle instead of
//! reducing chunk `k+1`. The pipelining subsystem splits a dispatch
//! into `depth` chunk windows over the existing
//! [`crate::collectives::Chunks`] boundary math and interleaves their
//! legs in a wavefront (see
//! `crate::collectives::hierarchical`): at wavefront step `s`, chunk
//! `c` runs leg `s − c`, so chunk `k`'s internode exchange overlaps
//! chunk `k+1`'s intranode reduce, and each chunk's compression
//! kernels run on their own GPU stream
//! ([`crate::gpu::StreamId::NonDefault`]) so kernel time overlaps wire
//! time on both execution backends.
//!
//! **Depth is a tuned axis.** [`choose_depth`] prices every candidate
//! depth with [`crate::topo::Schedule::estimate_makespan_pipelined`] —
//! `Σ legs c(B/d) + (d−1)·max_leg c(B/d)` — and the dispatcher picks
//! the argmin the same way the tuner picks algo, codec, and eb.
//! Per-chunk alpha and kernel-launch floors make the estimate convex
//! in practice: depth 1 wins tiny messages, interior depths win large
//! ones.
//!
//! **Surface.** [`crate::comm::Communicator::icollective`] dispatches
//! on a worker thread and returns a waitable [`CollectiveHandle`];
//! [`crate::comm::Communicator::persistent`] plans/compiles/budgets a
//! collective once and returns a [`PersistentColl`] whose `run`/`irun`
//! skip all per-dispatch planning — what a DDP step loop needs to
//! overlap backward compute with its gradient allreduce
//! (`examples/pipeline_tour.rs`).
//!
//! Accuracy propagation is untouched: every element still crosses the
//! same legs and the same compressors, only sliced into windows — the
//! per-element stage count (and therefore the amplification model) is
//! identical at every depth.

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::collectives::MAX_PIPELINE_DEPTH;
use crate::comm::communicator::{CollectiveReport, PlannedDispatch};
use crate::comm::Communicator;
use crate::coordinator::DeviceBuf;
use crate::error::{Error, Result};
use crate::topo::{CostModel, Schedule, TierTree};

/// How a [`Communicator`] chooses pipeline depth at dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Pipeline {
    /// Price every depth up to [`MAX_PIPELINE_DEPTH`] with the cost
    /// model and run the argmin (the default).
    #[default]
    Auto,
    /// Barrier execution: every dispatch runs at depth 1.
    Off,
    /// Always run this depth (clamped to
    /// `1..=`[`MAX_PIPELINE_DEPTH`]).
    Fixed(usize),
}

impl Pipeline {
    /// Parse the CLI form: `auto`, `off`, or an explicit depth.
    pub fn parse(s: &str) -> Result<Pipeline> {
        match s {
            "auto" => Ok(Pipeline::Auto),
            "off" => Ok(Pipeline::Off),
            d => d
                .parse::<usize>()
                .ok()
                .filter(|d| *d >= 1)
                .map(Pipeline::Fixed)
                .ok_or_else(|| {
                    Error::config(format!(
                        "--pipeline must be auto, off, or a depth >= 1 (got {s:?})"
                    ))
                }),
        }
    }
}

impl std::fmt::Display for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Pipeline::Auto => write!(f, "auto"),
            Pipeline::Off => write!(f, "off"),
            Pipeline::Fixed(d) => write!(f, "{d}"),
        }
    }
}

/// Pick the pipeline depth for `sched` over a `msg_bytes` dispatch:
/// the depth in `1..=`[`MAX_PIPELINE_DEPTH`] minimizing
/// [`Schedule::estimate_makespan_pipelined`] on `phys` under `cost`.
/// Ties go to the shallower depth, so depth 1 (the barrier executor,
/// whose behavior is bit-identical to the historical one) is kept
/// whenever chunking buys nothing.
pub fn choose_depth(
    sched: &Schedule,
    phys: &TierTree,
    cost: &CostModel,
    msg_bytes: usize,
) -> usize {
    let mut best_d = 1;
    let mut best = sched.estimate_makespan_pipelined(phys, cost, msg_bytes, 1);
    for d in 2..=MAX_PIPELINE_DEPTH {
        let est = sched.estimate_makespan_pipelined(phys, cost, msg_bytes, d);
        if est < best {
            best = est;
            best_d = d;
        }
    }
    best_d
}

/// A waitable in-flight collective, returned by
/// [`Communicator::icollective`] and [`PersistentColl::irun`]: the
/// dispatch runs on a worker thread while the caller overlaps other
/// work (a DDP backward pass), then [`CollectiveHandle::wait`] joins
/// it and hands back the full [`CollectiveReport`].
pub struct CollectiveHandle {
    join: JoinHandle<Result<CollectiveReport>>,
}

impl CollectiveHandle {
    pub(crate) fn spawn(
        f: impl FnOnce() -> Result<CollectiveReport> + Send + 'static,
    ) -> Self {
        CollectiveHandle {
            join: std::thread::spawn(f),
        }
    }

    /// Whether the collective has finished (wait would not block).
    pub fn is_finished(&self) -> bool {
        self.join.is_finished()
    }

    /// Block until the collective completes and return its report.
    pub fn wait(self) -> Result<CollectiveReport> {
        self.join
            .join()
            .map_err(|_| Error::collective("icollective worker thread panicked"))?
    }
}

/// A plan-once/run-many collective: algorithm selection, schedule
/// compilation, budget splitting, codec override, and depth selection
/// all ran once at [`Communicator::persistent`]; every
/// [`PersistentColl::run`] (or non-blocking [`PersistentColl::irun`])
/// executes the frozen plan directly, so per-step dispatch cost
/// amortizes across a training loop.
#[derive(Clone)]
pub struct PersistentColl {
    pub(crate) comm: Communicator,
    pub(crate) planned: Arc<PlannedDispatch>,
}

impl PersistentColl {
    /// The algorithm the plan runs.
    pub fn algo(&self) -> crate::collectives::Algo {
        self.planned.algo
    }

    /// The operation the plan realizes.
    pub fn op(&self) -> crate::collectives::Op {
        self.planned.op
    }

    /// The pipeline depth the plan executes at.
    pub fn depth(&self) -> usize {
        self.planned.exec_plan.depth
    }

    /// The frozen execution plan (per-leg compression directives).
    pub fn exec_plan(&self) -> &crate::topo::ExecPlan {
        &self.planned.exec_plan
    }

    /// The compiled hierarchical schedule, when the plan is scheduled.
    pub fn schedule(&self) -> Option<&Schedule> {
        self.planned.schedule.as_ref()
    }

    /// Run the frozen plan synchronously.
    pub fn run(&self, inputs: Vec<DeviceBuf>) -> Result<CollectiveReport> {
        self.comm.run_planned(&self.planned, inputs)
    }

    /// Run the frozen plan on a worker thread; overlap compute, then
    /// [`CollectiveHandle::wait`].
    pub fn irun(&self, inputs: Vec<DeviceBuf>) -> CollectiveHandle {
        let comm = self.comm.clone();
        let planned = Arc::clone(&self.planned);
        CollectiveHandle::spawn(move || comm.run_planned(&planned, inputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::compile_min_error;

    #[test]
    fn pipeline_knob_parses_and_prints() {
        assert_eq!(Pipeline::parse("auto").unwrap(), Pipeline::Auto);
        assert_eq!(Pipeline::parse("off").unwrap(), Pipeline::Off);
        assert_eq!(Pipeline::parse("4").unwrap(), Pipeline::Fixed(4));
        assert!(Pipeline::parse("0").is_err());
        assert!(Pipeline::parse("deep").is_err());
        assert_eq!(Pipeline::Auto.to_string(), "auto");
        assert_eq!(Pipeline::Fixed(2).to_string(), "2");
    }

    #[test]
    fn depth_choice_follows_the_cost_model() {
        use crate::collectives::Op;
        let tree = TierTree::new(512, &[4, 16, 8]).unwrap();
        let cost = CostModel::default_a100();
        let sched = compile_min_error(Op::Allreduce, &tree, true).unwrap();
        // Large message: chunking overlaps the bottleneck leg → the
        // chooser leaves depth 1 behind.
        let big = choose_depth(&sched, &tree, &cost, 64 << 20);
        assert!(big > 1, "64 MiB should pipeline (got depth {big})");
        assert!(big <= MAX_PIPELINE_DEPTH);
        // Tiny message: per-chunk latency floors dominate → barrier.
        assert_eq!(choose_depth(&sched, &tree, &cost, 1 << 10), 1);
        // The choice is the argmin of the pipelined estimate.
        let best = sched.estimate_makespan_pipelined(&tree, &cost, 64 << 20, big);
        for d in 1..=MAX_PIPELINE_DEPTH {
            assert!(
                best <= sched.estimate_makespan_pipelined(&tree, &cost, 64 << 20, d),
                "depth {big} must be no worse than depth {d}"
            );
        }
    }
}
