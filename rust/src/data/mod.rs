//! Datasets and accuracy metrics.
//!
//! The paper evaluates on two RTM (reverse-time-migration) wavefield
//! datasets from the 3D SEG/EAGE Overthrust model (449×449×235 and
//! 849×849×235) plus an image-stacking workload. We do not have the
//! proprietary data, so [`rtm`] synthesizes wavefields of the same
//! dimensions and smoothness class (superposed Ricker wavefronts over a
//! smooth background), which puts the cuSZp-class compressor in the same
//! compression-ratio regime (Table 1). [`images`] synthesizes stacking
//! inputs; [`metrics`] implements PSNR and NRMSE exactly as the paper
//! reports them.

pub mod images;
pub mod metrics;
pub mod rtm;

pub use metrics::{nrmse, psnr};
pub use rtm::RtmDataset;
