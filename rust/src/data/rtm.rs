//! Synthetic RTM-like wavefield datasets.
//!
//! Substitutes for the paper's two proprietary RTM snapshots (3D
//! SEG/EAGE Overthrust, GeoDRIVE): same grid dimensions, same smoothness
//! class. A wavefield snapshot is a superposition of expanding Ricker
//! wavefronts from a few source points over a smooth background — smooth
//! along the fast (x) axis, which is what a 1D-Lorenzo compressor keys
//! on, with localized high-frequency energy near the wavefronts so the
//! compression ratio is finite and error-bound-dependent (Table 1).

use crate::testkit::Pcg32;

/// Ricker wavelet ψ(t) = (1 − 2π²t²)·exp(−π²t²).
pub fn ricker(t: f64) -> f64 {
    let a = std::f64::consts::PI * std::f64::consts::PI * t * t;
    (1.0 - 2.0 * a) * (-a).exp()
}

/// One synthetic wavefield source.
#[derive(Debug, Clone, Copy)]
struct Source {
    cx: f64,
    cy: f64,
    cz: f64,
    /// Wavefront radius (grid units).
    radius: f64,
    /// Wavelength of the front.
    width: f64,
    amp: f64,
}

/// A synthetic RTM-like dataset of fixed dimensions.
#[derive(Debug, Clone)]
pub struct RtmDataset {
    /// Grid dims (nx = fastest axis, matching the paper's X×Y×Z).
    pub nx: usize,
    /// Second axis.
    pub ny: usize,
    /// Slowest axis.
    pub nz: usize,
    /// Descriptive name used in reports.
    pub name: &'static str,
    sources: Vec<Source>,
}

impl RtmDataset {
    /// Paper "Simulation Setting 1": 449×449×235 ≈ 189 MB of f32
    /// (reported as the ~180 MB dataset in Fig. 6a).
    pub fn setting1() -> Self {
        Self::synthesize("RTM-1 (449x449x235)", 449, 449, 235, 0x51E5_EED1)
    }

    /// Paper "Simulation Setting 2": 849×849×235 ≈ 677 MB of f32 (the
    /// "646 MB" full dataset of the scalability studies).
    pub fn setting2() -> Self {
        Self::synthesize("RTM-2 (849x849x235)", 849, 849, 235, 0x51E5_EED2)
    }

    /// A small dataset for unit tests (64×64×32).
    pub fn tiny() -> Self {
        Self::synthesize("RTM-tiny (64x64x32)", 64, 64, 32, 0x7E57)
    }

    fn synthesize(name: &'static str, nx: usize, ny: usize, nz: usize, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let n_sources = 4;
        let sources = (0..n_sources)
            .map(|_| Source {
                cx: rng.range_f32(0.2, 0.8) as f64 * nx as f64,
                cy: rng.range_f32(0.2, 0.8) as f64 * ny as f64,
                cz: rng.range_f32(0.1, 0.9) as f64 * nz as f64,
                // Early-time snapshot: compact wavefronts, most of the
                // volume still quiet — the property that gives cuSZp
                // its large ratios on real RTM snapshots.
                radius: rng.range_f32(0.05, 0.2) as f64 * nx as f64,
                width: rng.range_f32(4.0, 8.0) as f64,
                amp: rng.range_f32(0.3, 1.0) as f64,
            })
            .collect();
        RtmDataset {
            nx,
            ny,
            nz,
            name,
            sources,
        }
    }

    /// Total number of f32 values.
    pub fn total_values(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Total dataset size in bytes.
    pub fn total_bytes(&self) -> usize {
        self.total_values() * 4
    }

    /// Field value at grid point (i, j, k).
    pub fn value_at(&self, i: usize, j: usize, k: usize) -> f32 {
        let (x, y, z) = (i as f64, j as f64, k as f64);
        let mut v = 0.0f64;
        for s in &self.sources {
            let dx = x - s.cx;
            let dy = y - s.cy;
            let dz = z - s.cz;
            let r = (dx * dx + dy * dy + dz * dz).sqrt();
            let t = (r - s.radius) / s.width;
            // Truncated support: the field is exactly quiet away from
            // the fronts, as in an early-time wavefield snapshot.
            if t.abs() < 3.0 {
                v += s.amp * ricker(t);
            }
        }
        // Very-low-amplitude smooth background: invisible at loose
        // error bounds, material only when eb tightens below ~1e-5.
        v += 1e-4 * (x * 0.0037).sin() * (y * 0.0041).cos() * (z * 0.0043).sin();
        v as f32
    }

    /// Generate one z-plane (`nx × ny` values, x fastest).
    pub fn plane(&self, k: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.nx * self.ny);
        for j in 0..self.ny {
            for i in 0..self.nx {
                out.push(self.value_at(i, j, k));
            }
        }
        out
    }

    /// Generate the first `n` values of the dataset (x fastest). Used
    /// to sample compression profiles without materializing 677 MB.
    pub fn sample(&self, n: usize) -> Vec<f32> {
        let n = n.min(self.total_values());
        let mut out = Vec::with_capacity(n);
        let plane = self.nx * self.ny;
        let mut k = 0;
        while out.len() < n {
            let p = self.plane(k);
            let take = (n - out.len()).min(plane);
            out.extend_from_slice(&p[..take]);
            k += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{ratio, Compressor, CuszpLike};
    use crate::data::metrics::psnr;

    #[test]
    fn ricker_shape() {
        assert!((ricker(0.0) - 1.0).abs() < 1e-12);
        assert!(ricker(1.0) < 0.0); // side lobe
        assert!(ricker(5.0).abs() < 1e-9); // decays
    }

    #[test]
    fn dims_match_paper() {
        let d1 = RtmDataset::setting1();
        assert_eq!((d1.nx, d1.ny, d1.nz), (449, 449, 235));
        // ~180 MB
        assert!((170_000_000..200_000_000).contains(&d1.total_bytes()));
        let d2 = RtmDataset::setting2();
        assert_eq!((d2.nx, d2.ny, d2.nz), (849, 849, 235));
        // The paper's "646 MB" dataset.
        assert!((600_000_000..700_000_000).contains(&d2.total_bytes()));
    }

    #[test]
    fn field_is_deterministic_and_bounded() {
        let d = RtmDataset::tiny();
        let a = d.plane(3);
        let b = d.plane(3);
        assert_eq!(a, b);
        assert!(a.iter().all(|x| x.abs() < 10.0));
        // Non-trivial content.
        assert!(a.iter().any(|x| x.abs() > 0.01));
    }

    #[test]
    fn sample_truncates_and_concatenates_planes() {
        let d = RtmDataset::tiny();
        let s = d.sample(d.nx * d.ny + 7);
        assert_eq!(s.len(), d.nx * d.ny + 7);
        assert_eq!(&s[..d.nx * d.ny], &d.plane(0)[..]);
        assert_eq!(&s[d.nx * d.ny..], &d.plane(1)[..7]);
        // Request beyond the dataset clamps.
        assert_eq!(d.sample(usize::MAX).len(), d.total_values());
    }

    #[test]
    fn compression_ratio_lands_in_table1_regime() {
        // Table 1: CR ≈ 46–94 for eb 1e-3..1e-5 on the real RTM data.
        // Our synthetic stand-in must land in the same order of
        // magnitude for the performance model to transfer.
        let d = RtmDataset::setting1();
        let sample = d.sample(2_000_000);
        let raw = sample.len() * 4;
        let c3 = CuszpLike::new(1e-3);
        let r3 = ratio(raw, c3.compress(&sample).len());
        let c5 = CuszpLike::new(1e-5);
        let r5 = ratio(raw, c5.compress(&sample).len());
        assert!(r3 > 20.0, "eb=1e-3 ratio {r3} too low");
        assert!(r5 > 8.0, "eb=1e-5 ratio {r5} too low");
        assert!(r3 > r5, "looser bound must compress more");
    }

    #[test]
    fn reconstruction_psnr_tracks_error_bound() {
        let d = RtmDataset::tiny();
        let sample = d.sample(50_000);
        for (eb, min_psnr) in [(1e-3, 45.0), (1e-4, 60.0), (1e-5, 75.0)] {
            let c = CuszpLike::new(eb);
            let back = c.decompress(&c.compress(&sample)).unwrap();
            let p = psnr(&sample, &back);
            assert!(p > min_psnr, "eb={eb}: psnr {p} < {min_psnr}");
        }
    }
}
