//! Accuracy metrics: PSNR, NRMSE and L∞, as reported in Table 1 /
//! Fig. 13.
//!
//! **NaN policy.** A single NaN used to poison comparisons *silently*
//! (`value_range` skipped NaNs, `rmse` propagated them through
//! arithmetic). The plain functions now follow one documented rule:
//! **any NaN anywhere in the inputs makes the result NaN** — loudly
//! wrong instead of quietly wrong. The `try_*` variants return a typed
//! [`Error::Metrics`] instead, for callers (telemetry, planners) that
//! must distinguish "bad data" from "bad score".

use crate::error::{Error, Result};

fn has_nan(a: &[f32]) -> bool {
    a.iter().any(|x| x.is_nan())
}

/// Root-mean-square error between two equal-length slices.
///
/// NaN in either input (or a matching ∞ pair, whose difference is
/// undefined) yields NaN. Empty inputs yield 0.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    if has_nan(a) || has_nan(b) {
        return f64::NAN;
    }
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum();
    (sum / a.len() as f64).sqrt()
}

/// Maximum absolute pointwise deviation (L∞) between two equal-length
/// slices. NaN in either input yields NaN; empty inputs yield 0.
pub fn linf(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "linf: length mismatch");
    if has_nan(a) || has_nan(b) {
        return f64::NAN;
    }
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .fold(0.0, f64::max)
}

/// Value range (max − min) of a slice. Any NaN yields NaN (the old
/// behaviour silently skipped NaNs); an empty slice yields 0.
pub fn value_range(a: &[f32]) -> f64 {
    if has_nan(a) {
        return f64::NAN;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in a {
        let x = x as f64;
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    if lo > hi {
        0.0
    } else {
        hi - lo
    }
}

/// Peak signal-to-noise ratio in dB, with the peak taken as the value
/// range of the reference data (the convention used by SZ/cuSZp and the
/// paper's Table 1). NaN inputs yield NaN; an exact match yields +∞; a
/// zero-range reference with nonzero error yields −∞.
pub fn psnr(reference: &[f32], reconstructed: &[f32]) -> f64 {
    let e = rmse(reference, reconstructed);
    let range = value_range(reference);
    if e.is_nan() || range.is_nan() {
        return f64::NAN;
    }
    if e == 0.0 {
        return f64::INFINITY;
    }
    if range == 0.0 {
        return f64::NEG_INFINITY;
    }
    20.0 * (range / e).log10()
}

/// Normalized root-mean-square error: RMSE / value range. NaN inputs
/// yield NaN; a zero-range reference yields 0 (the historical
/// convention).
pub fn nrmse(reference: &[f32], reconstructed: &[f32]) -> f64 {
    let range = value_range(reference);
    if range.is_nan() {
        return f64::NAN;
    }
    if range == 0.0 {
        return 0.0;
    }
    rmse(reference, reconstructed) / range
}

fn check_pair(a: &[f32], b: &[f32], what: &str) -> Result<()> {
    if a.len() != b.len() {
        return Err(Error::metrics(format!(
            "{what}: length mismatch ({} vs {})",
            a.len(),
            b.len()
        )));
    }
    if has_nan(a) || has_nan(b) {
        return Err(Error::metrics(format!("{what}: NaN in input")));
    }
    Ok(())
}

/// Checked [`rmse`]: typed error on length mismatch or NaN input.
pub fn try_rmse(a: &[f32], b: &[f32]) -> Result<f64> {
    check_pair(a, b, "rmse")?;
    Ok(rmse(a, b))
}

/// Checked [`linf`]: typed error on length mismatch or NaN input.
pub fn try_linf(a: &[f32], b: &[f32]) -> Result<f64> {
    check_pair(a, b, "linf")?;
    Ok(linf(a, b))
}

/// Checked [`psnr`]: typed error on length mismatch, NaN input, or a
/// zero-range reference (for which PSNR is meaningless). An exact match
/// still yields +∞.
pub fn try_psnr(reference: &[f32], reconstructed: &[f32]) -> Result<f64> {
    check_pair(reference, reconstructed, "psnr")?;
    if value_range(reference) == 0.0 {
        return Err(Error::metrics("psnr: zero-range reference"));
    }
    Ok(psnr(reference, reconstructed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_data_is_perfect() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert_eq!(rmse(&a, &a), 0.0);
        assert_eq!(linf(&a, &a), 0.0);
        assert!(psnr(&a, &a).is_infinite());
        assert_eq!(nrmse(&a, &a), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let a = vec![0.0f32, 0.0];
        let b = vec![3.0f32, 4.0];
        // sqrt((9+16)/2) = sqrt(12.5)
        assert!((rmse(&a, &b) - 12.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(linf(&a, &b), 4.0);
    }

    #[test]
    fn psnr_nrmse_consistent() {
        // PSNR = -20 log10(NRMSE).
        let a: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.01).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 0.001).collect();
        let p = psnr(&a, &b);
        let n = nrmse(&a, &b);
        assert!((p + 20.0 * n.log10()).abs() < 1e-9);
    }

    #[test]
    fn uniform_quantization_psnr_formula() {
        // Quantizing with max error eb over range R gives
        // NRMSE ≈ eb/(sqrt(3)·R) for uniform error — PSNR ≈
        // 20·log10(R·sqrt(3)/eb). Sanity check the order of magnitude,
        // mirroring Table 1's eb → PSNR relationship.
        let n = 100_000;
        let range = 2.0f32;
        let eb = 1e-3f32;
        let a: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) * range - 1.0).collect();
        let b: Vec<f32> = a
            .iter()
            .map(|x| ((x / (2.0 * eb)).round()) * 2.0 * eb)
            .collect();
        let p = psnr(&a, &b);
        assert!((60.0..80.0).contains(&p), "psnr {p}");
    }

    #[test]
    fn value_range_handles_empty_and_constant() {
        assert_eq!(value_range(&[]), 0.0);
        assert_eq!(value_range(&[5.0; 10]), 0.0);
        assert_eq!(value_range(&[-1.0, 4.0]), 5.0);
    }

    #[test]
    fn nan_makes_every_metric_nan_not_silent() {
        let clean = vec![1.0f32, 2.0, 3.0];
        let dirty = vec![1.0f32, f32::NAN, 3.0];
        // Regression: value_range used to skip the NaN and report 2.0.
        assert!(value_range(&dirty).is_nan());
        assert!(rmse(&clean, &dirty).is_nan());
        assert!(rmse(&dirty, &clean).is_nan());
        assert!(linf(&clean, &dirty).is_nan());
        assert!(psnr(&dirty, &clean).is_nan());
        assert!(psnr(&clean, &dirty).is_nan());
        assert!(nrmse(&dirty, &clean).is_nan());
    }

    #[test]
    fn empty_and_zero_range_edges() {
        assert_eq!(rmse(&[], &[]), 0.0);
        assert_eq!(linf(&[], &[]), 0.0);
        // Zero-range reference: -inf PSNR for nonzero error, 0 NRMSE.
        let flat = vec![2.0f32; 8];
        let off: Vec<f32> = flat.iter().map(|x| x + 0.5).collect();
        assert_eq!(psnr(&flat, &off), f64::NEG_INFINITY);
        assert_eq!(nrmse(&flat, &off), 0.0);
    }

    #[test]
    fn try_variants_return_typed_errors() {
        let clean = vec![1.0f32, 2.0];
        let dirty = vec![1.0f32, f32::NAN];
        let short = vec![1.0f32];
        for err in [
            try_rmse(&clean, &dirty).unwrap_err(),
            try_linf(&dirty, &clean).unwrap_err(),
            try_psnr(&clean, &dirty).unwrap_err(),
            try_rmse(&clean, &short).unwrap_err(),
        ] {
            assert!(matches!(err, crate::error::Error::Metrics(_)), "{err}");
        }
        assert!(try_psnr(&[3.0, 3.0], &[3.0, 3.1]).is_err(), "zero range");
        assert!((try_rmse(&clean, &clean).unwrap()).abs() < 1e-12);
        assert!(try_psnr(&clean, &clean).unwrap().is_infinite());
    }
}
