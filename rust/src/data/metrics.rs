//! Accuracy metrics: PSNR and NRMSE, as reported in Table 1 / Fig. 13.

/// Root-mean-square error between two equal-length slices.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse: length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    let sum: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum();
    (sum / a.len() as f64).sqrt()
}

/// Value range (max − min) of a slice.
pub fn value_range(a: &[f32]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in a {
        let x = x as f64;
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    if lo > hi {
        0.0
    } else {
        hi - lo
    }
}

/// Peak signal-to-noise ratio in dB, with the peak taken as the value
/// range of the reference data (the convention used by SZ/cuSZp and the
/// paper's Table 1).
pub fn psnr(reference: &[f32], reconstructed: &[f32]) -> f64 {
    let e = rmse(reference, reconstructed);
    let range = value_range(reference);
    if e == 0.0 {
        return f64::INFINITY;
    }
    if range == 0.0 {
        return f64::NEG_INFINITY;
    }
    20.0 * (range / e).log10()
}

/// Normalized root-mean-square error: RMSE / value range.
pub fn nrmse(reference: &[f32], reconstructed: &[f32]) -> f64 {
    let range = value_range(reference);
    if range == 0.0 {
        return 0.0;
    }
    rmse(reference, reconstructed) / range
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_data_is_perfect() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert_eq!(rmse(&a, &a), 0.0);
        assert!(psnr(&a, &a).is_infinite());
        assert_eq!(nrmse(&a, &a), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let a = vec![0.0f32, 0.0];
        let b = vec![3.0f32, 4.0];
        // sqrt((9+16)/2) = sqrt(12.5)
        assert!((rmse(&a, &b) - 12.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn psnr_nrmse_consistent() {
        // PSNR = -20 log10(NRMSE).
        let a: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.01).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 0.001).collect();
        let p = psnr(&a, &b);
        let n = nrmse(&a, &b);
        assert!((p + 20.0 * n.log10()).abs() < 1e-9);
    }

    #[test]
    fn uniform_quantization_psnr_formula() {
        // Quantizing with max error eb over range R gives
        // NRMSE ≈ eb/(sqrt(3)·R) for uniform error — PSNR ≈
        // 20·log10(R·sqrt(3)/eb). Sanity check the order of magnitude,
        // mirroring Table 1's eb → PSNR relationship.
        let n = 100_000;
        let range = 2.0f32;
        let eb = 1e-3f32;
        let a: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) * range - 1.0).collect();
        let b: Vec<f32> = a
            .iter()
            .map(|x| ((x / (2.0 * eb)).round()) * 2.0 * eb)
            .collect();
        let p = psnr(&a, &b);
        assert!((60.0..80.0).contains(&p), "psnr {p}");
    }

    #[test]
    fn value_range_handles_empty_and_constant() {
        assert_eq!(value_range(&[]), 0.0);
        assert_eq!(value_range(&[5.0; 10]), 0.0);
        assert_eq!(value_range(&[-1.0, 4.0]), 5.0);
    }
}
