//! Synthetic image-stacking inputs (paper §4.5).
//!
//! Image stacking sums many per-process partial images into one final
//! image — "essentially an Allreduce" (the paper, citing Gurhem 2021's
//! Kirchhoff migration). We synthesize a ground-truth scene and split it
//! into per-rank partials whose exact sum reproduces the scene plus
//! small incoherent noise, mirroring how migration partial images carry
//! coherent signal plus shot noise.

use crate::testkit::Pcg32;

/// An image-stacking scenario: `ranks` partial images of `width×height`.
#[derive(Debug, Clone)]
pub struct StackingScenario {
    /// Image width.
    pub width: usize,
    /// Image height.
    pub height: usize,
    /// Number of partial images (= ranks in the Allreduce).
    pub ranks: usize,
    seed: u64,
}

impl StackingScenario {
    /// Construct a scenario.
    pub fn new(width: usize, height: usize, ranks: usize, seed: u64) -> Self {
        assert!(ranks > 0 && width > 0 && height > 0);
        StackingScenario {
            width,
            height,
            ranks,
            seed,
        }
    }

    /// Pixels per image.
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// The ground-truth scene: a handful of Gaussian reflectors plus a
    /// dipping-layer texture (seismic-section flavored).
    pub fn truth(&self) -> Vec<f32> {
        let mut rng = Pcg32::seeded(self.seed);
        let nblobs = 8;
        let blobs: Vec<(f64, f64, f64, f64)> = (0..nblobs)
            .map(|_| {
                (
                    rng.range_f32(0.1, 0.9) as f64 * self.width as f64,
                    rng.range_f32(0.1, 0.9) as f64 * self.height as f64,
                    rng.range_f32(3.0, 20.0) as f64,
                    rng.range_f32(-1.0, 1.0) as f64,
                )
            })
            .collect();
        let mut img = Vec::with_capacity(self.pixels());
        for y in 0..self.height {
            for x in 0..self.width {
                let (xf, yf) = (x as f64, y as f64);
                let mut v = 0.0;
                for &(cx, cy, s, a) in &blobs {
                    let d2 = (xf - cx).powi(2) + (yf - cy).powi(2);
                    v += a * (-d2 / (2.0 * s * s)).exp();
                }
                // Dipping layers.
                v += 0.2 * ((xf * 0.05 + yf * 0.11).sin());
                img.push(v as f32);
            }
        }
        img
    }

    /// Partial image for `rank`: `truth/ranks` plus per-rank noise of
    /// amplitude `noise`. Summing all partials reproduces the truth up
    /// to the (incoherent, mean-zero) noise.
    pub fn partial(&self, rank: usize, noise: f32) -> Vec<f32> {
        assert!(rank < self.ranks);
        let truth = self.truth();
        let mut rng = Pcg32::new(self.seed ^ 0xABCD, rank as u64 + 1);
        truth
            .iter()
            .map(|v| v / self.ranks as f32 + rng.next_gaussian() * noise)
            .collect()
    }

    /// The exact (lossless) stack: elementwise sum of all partials.
    pub fn exact_stack(&self, noise: f32) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.pixels()];
        for r in 0..self.ranks {
            for (a, v) in acc.iter_mut().zip(self.partial(r, noise)) {
                *a += v;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::metrics::psnr;

    #[test]
    fn truth_is_deterministic_nontrivial() {
        let s = StackingScenario::new(64, 48, 4, 9);
        let a = s.truth();
        assert_eq!(a.len(), 64 * 48);
        assert_eq!(a, s.truth());
        let range: f32 = a.iter().fold(f32::MIN, |m, &x| m.max(x))
            - a.iter().fold(f32::MAX, |m, &x| m.min(x));
        assert!(range > 0.1);
    }

    #[test]
    fn noiseless_partials_sum_to_truth() {
        let s = StackingScenario::new(32, 32, 8, 11);
        let stack = s.exact_stack(0.0);
        let truth = s.truth();
        for (a, b) in stack.iter().zip(truth.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn noisy_stack_close_to_truth() {
        let s = StackingScenario::new(64, 64, 16, 13);
        let stack = s.exact_stack(0.01);
        let p = psnr(&s.truth(), &stack);
        // Incoherent noise averages down: the stack should still be a
        // high-quality image.
        assert!(p > 25.0, "psnr {p}");
    }

    #[test]
    fn partials_differ_across_ranks() {
        let s = StackingScenario::new(16, 16, 4, 17);
        assert_ne!(s.partial(0, 0.01), s.partial(1, 0.01));
    }

    #[test]
    #[should_panic]
    fn partial_rank_out_of_range_panics() {
        let s = StackingScenario::new(8, 8, 2, 1);
        s.partial(2, 0.0);
    }
}
