//! Per-rank GPU device state: stream timelines + PCIe engines.

use crate::sim::{Timeline, VirtTime};

use super::model::GpuModel;

/// Identifies a stream on a device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamId {
    /// The default (NULL) stream.
    Default,
    /// A numbered non-default stream (gZCCL creates one per chunk in
    /// the multi-stream Scatter path, and one "compression stream" in
    /// the Allreduce path).
    NonDefault(usize),
}

/// One simulated GPU: the model parameters plus the resource timelines
/// that give overlap/pipelining semantics.
#[derive(Debug, Clone)]
pub struct GpuDevice {
    model: GpuModel,
    default_stream: Timeline,
    streams: Vec<Timeline>,
    /// Host→device copy engine.
    h2d: Timeline,
    /// Device→host copy engine.
    d2h: Timeline,
}

impl GpuDevice {
    /// A device with `n_streams` non-default streams.
    pub fn new(model: GpuModel, n_streams: usize) -> Self {
        GpuDevice {
            model,
            default_stream: Timeline::new(),
            streams: (0..n_streams).map(|_| Timeline::new()).collect(),
            h2d: Timeline::new(),
            d2h: Timeline::new(),
        }
    }

    /// The device's cost-model parameters.
    pub fn model(&self) -> &GpuModel {
        &self.model
    }

    /// Number of non-default streams.
    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Ensure at least `n` non-default streams exist (gZ-Scatter sizes
    /// its stream array to the communicator size at runtime).
    pub fn ensure_streams(&mut self, n: usize) {
        while self.streams.len() < n {
            self.streams.push(Timeline::new());
        }
    }

    fn stream_mut(&mut self, s: StreamId) -> &mut Timeline {
        match s {
            StreamId::Default => &mut self.default_stream,
            StreamId::NonDefault(i) => {
                self.ensure_streams(i + 1);
                &mut self.streams[i]
            }
        }
    }

    /// Enqueue `dur` seconds of kernel work on stream `s`, ready at
    /// `ready`. Returns the kernel's completion timestamp.
    pub fn enqueue(&mut self, s: StreamId, ready: VirtTime, dur: f64) -> VirtTime {
        let (_, end) = self.stream_mut(s).reserve(ready, dur);
        end
    }

    /// Timestamp at which stream `s` drains.
    pub fn stream_free(&mut self, s: StreamId) -> VirtTime {
        self.stream_mut(s).busy_until()
    }

    /// Timestamp at which *all* streams drain (device synchronize).
    pub fn device_free(&self) -> VirtTime {
        let mut t = self.default_stream.busy_until();
        for s in &self.streams {
            t = t.join(s.busy_until());
        }
        t.join(self.h2d.busy_until()).join(self.d2h.busy_until())
    }

    /// Reserve the device→host copy engine for `bytes`.
    pub fn copy_d2h(&mut self, ready: VirtTime, bytes: usize) -> VirtTime {
        let dur = self.model.pcie.transfer_time(bytes);
        let (_, end) = self.d2h.reserve(ready, dur);
        end
    }

    /// Reserve the host→device copy engine for `bytes`.
    pub fn copy_h2d(&mut self, ready: VirtTime, bytes: usize) -> VirtTime {
        let dur = self.model.pcie.transfer_time(bytes);
        let (_, end) = self.h2d.reserve(ready, dur);
        end
    }

    /// Total busy seconds over all streams (utilization diagnostics).
    pub fn streams_busy_total(&self) -> f64 {
        self.default_stream.busy_total() + self.streams.iter().map(|s| s.busy_total()).sum::<f64>()
    }

    /// Reset all timelines (between runs).
    pub fn reset(&mut self) {
        self.default_stream.reset();
        for s in &mut self.streams {
            s.reset();
        }
        self.h2d.reset();
        self.d2h.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> GpuDevice {
        GpuDevice::new(GpuModel::a100(), 2)
    }

    #[test]
    fn same_stream_serializes() {
        let mut d = dev();
        let e1 = d.enqueue(StreamId::Default, VirtTime::ZERO, 1.0);
        let e2 = d.enqueue(StreamId::Default, VirtTime::ZERO, 1.0);
        assert_eq!(e1, VirtTime::secs(1.0));
        assert_eq!(e2, VirtTime::secs(2.0));
    }

    #[test]
    fn different_streams_overlap() {
        let mut d = dev();
        let e1 = d.enqueue(StreamId::NonDefault(0), VirtTime::ZERO, 1.0);
        let e2 = d.enqueue(StreamId::NonDefault(1), VirtTime::ZERO, 1.0);
        assert_eq!(e1, e2);
    }

    #[test]
    fn device_free_joins_everything() {
        let mut d = dev();
        d.enqueue(StreamId::Default, VirtTime::ZERO, 1.0);
        d.enqueue(StreamId::NonDefault(1), VirtTime::ZERO, 3.0);
        d.copy_d2h(VirtTime::ZERO, 0);
        assert_eq!(d.device_free(), VirtTime::secs(3.0));
    }

    #[test]
    fn streams_grow_on_demand() {
        let mut d = dev();
        assert_eq!(d.n_streams(), 2);
        d.enqueue(StreamId::NonDefault(7), VirtTime::ZERO, 0.5);
        assert_eq!(d.n_streams(), 8);
    }

    #[test]
    fn copy_engines_are_independent_directions() {
        let mut d = dev();
        let n = 100 << 20;
        let t1 = d.copy_d2h(VirtTime::ZERO, n);
        let t2 = d.copy_h2d(VirtTime::ZERO, n);
        // Full duplex: both finish at the same time.
        assert_eq!(t1, t2);
        // Same direction serializes.
        let t3 = d.copy_d2h(VirtTime::ZERO, n);
        assert!(t3 > t1);
    }

    #[test]
    fn reset_restores_fresh_device() {
        let mut d = dev();
        d.enqueue(StreamId::Default, VirtTime::ZERO, 5.0);
        d.reset();
        assert_eq!(d.device_free(), VirtTime::ZERO);
        assert_eq!(d.streams_busy_total(), 0.0);
    }
}
