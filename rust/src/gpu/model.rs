//! Device cost-model parameters.

use crate::net::LinkModel;

/// Affine-with-floor kernel cost model:
///
/// `t(n) = launch + (n + n0) / beta`
///
/// `n0` is the *fixed work equivalent* — the bytes-worth of time a
/// kernel pays regardless of input size (grid setup, underfilled SMs).
/// For `n ≪ n0` the time stagnates at `launch + n0/beta`, reproducing
/// the knee the paper characterizes for cuSZp in Fig. 3; for `n ≫ n0`
/// the kernel runs at streaming bandwidth `beta`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelModel {
    /// Kernel launch overhead in seconds (host-visible).
    pub launch: f64,
    /// Fixed-work equivalent in bytes.
    pub n0: f64,
    /// Saturated throughput in bytes/second.
    pub beta: f64,
}

impl KernelModel {
    /// Build a model; panics on non-positive throughput.
    pub fn new(launch: f64, n0: f64, beta: f64) -> Self {
        assert!(beta > 0.0 && launch >= 0.0 && n0 >= 0.0, "bad kernel model");
        KernelModel { launch, n0, beta }
    }

    /// Execution time of one kernel over `bytes` of input.
    pub fn time(&self, bytes: usize) -> f64 {
        self.launch + (bytes as f64 + self.n0) / self.beta
    }

    /// Execution time of `k` same-stream sequential kernels, **each**
    /// over one `chunk_bytes`-sized chunk (total volume
    /// `k · chunk_bytes`): every kernel pays the full launch/fixed-work
    /// floor — no cross-kernel amortization. Contrast with
    /// [`KernelModel::time_multistream`], which takes the *summed*
    /// bytes and amortizes the floor across overlapped streams.
    pub fn time_sequential(&self, chunk_bytes: usize, k: usize) -> f64 {
        self.time(chunk_bytes) * k as f64
    }

    /// Execution time of `k` *multi-stream overlapped* kernels over
    /// chunks summing to `total_bytes`: the fixed work amortizes across
    /// streams (they fill the device together), and each extra stream
    /// costs only a small issue overhead.
    pub fn time_multistream(&self, total_bytes: usize, k: usize, stream_issue: f64) -> f64 {
        if k == 0 {
            return 0.0;
        }
        self.launch
            + stream_issue * (k.saturating_sub(1)) as f64
            + (total_bytes as f64 + self.n0) / self.beta
    }

    /// Input size (bytes) at which the kernel reaches utilization `u`
    /// (`0 < u < 1`) — the inverse of [`KernelModel::utilization`]:
    /// `n = u/(1−u) · (launch·β + n0)`. `u = 0.5` reproduces
    /// [`GpuModel::saturation_knee_bytes`]; the
    /// [`crate::comm::Tuner`] derives its compressed-ring chunk knee
    /// from this curve instead of a hard-coded constant.
    pub fn bytes_at_utilization(&self, u: f64) -> f64 {
        assert!(u > 0.0 && u < 1.0, "utilization must be in (0,1)");
        u / (1.0 - u) * (self.launch * self.beta + self.n0)
    }

    /// This kernel's cost scaled by `f` (launch down, throughput up by
    /// the same factor, fixed-work equivalent unchanged): a pipeline
    /// stage responsible for a share `f` of the whole kernel's time at
    /// every size. `time(n)` of the scaled model is exactly
    /// `f · time(n)` of the original.
    pub fn scaled(&self, f: f64) -> KernelModel {
        assert!(f > 0.0, "stage share must be positive");
        KernelModel {
            launch: self.launch * f,
            n0: self.n0,
            beta: self.beta / f,
        }
    }

    /// Effective utilization of a kernel at size `bytes`: ratio of
    /// streaming-rate time to actual time. 1.0 = fully saturated.
    pub fn utilization(&self, bytes: usize) -> f64 {
        let ideal = bytes as f64 / self.beta;
        let actual = self.time(bytes);
        if actual <= 0.0 {
            1.0
        } else {
            ideal / actual
        }
    }
}

/// Full per-GPU parameter set, A100-80GB-calibrated defaults.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuModel {
    /// Compression kernel (cuSZp-encode-class).
    pub compress: KernelModel,
    /// Decompression kernel (cuSZp-decode-class).
    pub decompress: KernelModel,
    /// Elementwise reduction kernel (HBM-bound: 2 reads + 1 write).
    pub reduce: KernelModel,
    /// Device memset.
    pub memset: KernelModel,
    /// Device-to-device copy (pack/unpack staging).
    pub d2d_copy: KernelModel,
    /// PCIe host↔device link.
    pub pcie: LinkModel,
    /// Host-side cost of issuing any async device op (cudaLaunchKernel).
    pub host_api: f64,
    /// Extra host cost of issuing on a non-default stream.
    pub stream_issue: f64,
    /// Host↔device synchronization overhead (cudaStreamSynchronize).
    pub sync: f64,
    /// Host reduction throughput in bytes/sec (CPU-centric baselines).
    pub host_reduce_beta: f64,
    /// Device-buffer allocation cost (paid when a variant does NOT use
    /// the pre-allocated pool — §3.3.1).
    pub alloc: f64,
}

impl GpuModel {
    /// A100-class defaults, calibrated against the *shapes* the paper
    /// reports rather than cuSZp's microbenchmarks alone:
    ///
    /// * Fig. 3 — compression time stagnates below ~5 MB (here the
    ///   floor extends to tens of MB: `t(5 MB) ≈ t(1 KB)`), declines
    ///   with decreasing rate above.
    /// * Fig. 9/10 — the floor is high enough that ring's 2(N−1)
    ///   chunk-kernels at 64 ranks cost more than NCCL's uncompressed
    ///   ring (gZ-Ring loses to NCCL at scale), while whole-vector
    ///   kernels stream fast enough that ReDoub wins by ~3–4×.
    pub fn a100() -> Self {
        GpuModel {
            compress: KernelModel::new(30e-6, 200.0e6, 350e9),
            decompress: KernelModel::new(25e-6, 160.0e6, 450e9),
            reduce: KernelModel::new(8e-6, 4.0e6, 600e9),
            memset: KernelModel::new(4e-6, 1.0e6, 2000e9),
            d2d_copy: KernelModel::new(6e-6, 2.0e6, 1000e9),
            pcie: LinkModel::pcie_default(),
            host_api: 4e-6,
            stream_issue: 2e-6,
            sync: 5e-6,
            host_reduce_beta: 40e9,
            alloc: 80e-6,
        }
    }

    /// The size at which a compression kernel reaches 50% of streaming
    /// throughput. Everything below is utilization-floor territory; the
    /// paper's Fig. 3 "stagnation below ~5 MB" is the flat left end of
    /// this regime.
    pub fn saturation_knee_bytes(&self) -> f64 {
        // Utilization 0.5 ⇒ n = launch·β + n0.
        self.compress.bytes_at_utilization(0.5)
    }

    /// Shares of the canonical compression pipeline's kernel time
    /// attributed to its `[predictor, quantizer, coder]` stages. The
    /// coder (bit packing with its shared-memory shuffle) dominates;
    /// prediction is a cheap neighboring-element subtract. The codec
    /// cost model scales each share by the composed stage's relative
    /// cost (see `CostModel::codec_kernel_factor`), and the per-stage
    /// throughput bench reports columns on the same split.
    pub fn stage_split() -> [f64; 3] {
        [0.2, 0.3, 0.5]
    }

    /// Per-stage kernel models of the compression pipeline: `compress`
    /// sliced by [`GpuModel::stage_split`], each stage keeping the full
    /// fixed-work floor profile at its share of launch and throughput.
    pub fn compress_stages(&self) -> [KernelModel; 3] {
        Self::stage_split().map(|f| self.compress.scaled(f))
    }

    /// Per-stage kernel models of the decompression pipeline.
    pub fn decompress_stages(&self) -> [KernelModel; 3] {
        Self::stage_split().map(|f| self.decompress.scaled(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_is_monotone_in_size() {
        let m = GpuModel::a100().compress;
        let mut prev = 0.0;
        for mb in [1usize, 2, 5, 10, 50, 100, 646] {
            let t = m.time(mb << 20);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn small_inputs_stagnate_fig3_shape() {
        // Fig. 3: below ~5 MB, execution time barely changes.
        let m = GpuModel::a100().compress;
        let t_5mb = m.time(5 << 20);
        let t_1kb = m.time(1 << 10);
        assert!(
            t_5mb / t_1kb < 1.1,
            "expected stagnation: t(5MB)={t_5mb} t(1KB)={t_1kb}"
        );
        // But the full 646 MB dataset is firmly in the streaming regime.
        let t_646mb = m.time(646 << 20);
        assert!(t_646mb / t_5mb > 4.0);
    }

    #[test]
    fn utilization_floor_regime() {
        let g = GpuModel::a100();
        let knee = g.saturation_knee_bytes();
        assert!(
            (100.0e6..400.0e6).contains(&knee),
            "50%-utilization knee {knee} out of calibrated range"
        );
        // 646 MB (the paper's full dataset) streams reasonably...
        assert!(g.compress.utilization(646 << 20) > 0.75);
        // ...while a 5 MB ring chunk (D/N at 128 ranks) is badly
        // under-utilized — the paper's §3.2.3 scalability cliff.
        assert!(g.compress.utilization(5 << 20) < 0.05);
    }

    #[test]
    fn many_small_cost_more_than_one_big() {
        // Paper §3.3.3: "10 times of compression of 1 MB data can be
        // much more expensive than 1 compression of [the same total]".
        let m = GpuModel::a100().compress;
        let ten_small = m.time_sequential(1 << 20, 10);
        let one_big = m.time(10 << 20);
        assert!(ten_small > 2.0 * one_big, "{ten_small} vs {one_big}");
    }

    #[test]
    fn multistream_amortizes_the_floor() {
        let m = GpuModel::a100().compress;
        let k = 16;
        let chunk = 1 << 20;
        let seq = m.time_sequential(chunk, k);
        let multi = m.time_multistream(chunk * k, k, 2e-6);
        assert!(
            multi < 0.5 * seq,
            "multi-stream {multi} should beat sequential {seq}"
        );
        // And can't beat the streaming lower bound.
        assert!(multi > (chunk * k) as f64 / m.beta);
    }

    #[test]
    fn multistream_zero_kernels_is_free() {
        let m = GpuModel::a100().compress;
        assert_eq!(m.time_multistream(0, 0, 2e-6), 0.0);
    }

    #[test]
    fn bytes_at_utilization_inverts_utilization() {
        let m = GpuModel::a100().compress;
        for u in [0.005, 0.1, 0.5, 0.9] {
            let n = m.bytes_at_utilization(u);
            assert!((m.utilization(n as usize) - u).abs() < 1e-3, "u {u}");
        }
        // The 50% point is exactly the saturation knee.
        let g = GpuModel::a100();
        assert!((g.compress.bytes_at_utilization(0.5) - g.saturation_knee_bytes()).abs() < 1e-6);
    }

    #[test]
    fn stage_split_partitions_the_kernel_time() {
        let g = GpuModel::a100();
        let split: f64 = GpuModel::stage_split().iter().sum();
        assert_eq!(split, 1.0);
        for n in [1usize << 10, 5 << 20, 646 << 20] {
            let total: f64 = g.compress_stages().iter().map(|m| m.time(n)).sum();
            assert!((total - g.compress.time(n)).abs() < 1e-9 * total, "n={n}");
            let total: f64 = g.decompress_stages().iter().map(|m| m.time(n)).sum();
            assert!((total - g.decompress.time(n)).abs() < 1e-9 * total, "n={n}");
        }
    }

    #[test]
    fn reduce_faster_than_compress() {
        let g = GpuModel::a100();
        let n = 64 << 20;
        assert!(g.reduce.time(n) < g.compress.time(n));
    }
}
