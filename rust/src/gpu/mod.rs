//! GPU device model.
//!
//! The paper's central observation (§3.2.2, Fig. 3) is that GPU
//! compression kernels have a *utilization floor*: execution time
//! decreases with input size only down to ~5 MB and then stagnates,
//! because launch overhead and fixed kernel cost dominate. Collective
//! algorithms that issue many small compressions (ring: N−1 chunks of
//! D/N) therefore lose to algorithms that issue few large ones
//! (recursive doubling: log N full-size ops) once D/N falls below the
//! saturation knee.
//!
//! * [`KernelModel`] — affine-with-floor kernel cost `t(n) = L + (n + n0)/β`,
//! * [`GpuModel`] — the full device parameter set (A100-calibrated),
//! * [`GpuDevice`] — per-rank stream timelines + PCIe engines.

pub mod device;
pub mod model;

pub use device::{GpuDevice, StreamId};
pub use model::{GpuModel, KernelModel};
