//! Trace-calibrated cost model: fit effective link and kernel
//! parameters from one recorded run and feed them back into planning.
//!
//! The analytic [`CostModel`] prices each leg from nameplate numbers:
//! per-tier `LinkModel`s, `KernelModel` throughputs, a static
//! stage-split kernel factor per codec. The simulated fabric is richer
//! — a message crosses nic-tx/uplink-tx/uplink-rx/nic-rx hops, queues
//! behind neighbors, and codec kernels run batched or multi-stream —
//! so predictions carry systematic error. This module closes the loop:
//! every sender-side `wire` span records (bytes, tier, queue-wait) and
//! every codec kernel span records its bytes, which is enough to fit
//!
//! * a per-tier **effective link**: least-squares `secs = α + bytes/β`
//!   over the queue-corrected wire samples of each crossing tier
//!   (falling back to a bandwidth-only fit when a tier saw only one
//!   message size),
//! * a per-codec **kernel factor**: the least-squares scale mapping
//!   the nameplate kernel time onto observed durations, and
//! * a per-codec **measured compression ratio** from the
//!   `cpr_{in,out}_bytes` counters.
//!
//! [`Calibration::apply`] grafts the fitted parameters onto a base
//! [`CostModel`]; `CommBuilder::calibrate_from` wires that into every
//! subsequent `compile_tuned` dispatch. The fit is deliberately
//! parametric (linear in bytes), so it transfers to message sizes the
//! trace never saw instead of memorizing the observed points.

use std::collections::BTreeMap;

use super::{Lane, SpanCat, SpanRec, TraceRun};
use crate::gpu::GpuModel;
use crate::net::LinkModel;
use crate::topo::CostModel;

/// One queue-corrected observation of a message on the wire.
#[derive(Debug, Clone, Copy)]
struct WireSample {
    bytes: f64,
    /// Span duration minus recorded queue wait: pure latency +
    /// serialization across every hop of the path.
    secs: f64,
}

/// Fitted corrections from one traced run. All fields are optional in
/// spirit: tiers or codecs the trace never exercised are simply absent
/// and [`Calibration::apply`] leaves the base model's values in place.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// Effective link per crossing tier (tier index as used by
    /// `CostModel::link`).
    pub links: BTreeMap<usize, LinkModel>,
    /// Effective kernel-time factor per codec label, pooled over
    /// compress and decompress samples.
    pub kernel_factors: Vec<(String, f64)>,
    /// Measured wire compression ratio per codec label.
    pub ratios: Vec<(String, f64)>,
    /// Wire spans consumed by the link fits.
    pub wire_samples: usize,
    /// Codec kernel spans consumed by the factor fits.
    pub kernel_samples: usize,
}

impl Calibration {
    /// True when the trace contained nothing to fit.
    pub fn is_empty(&self) -> bool {
        self.links.is_empty() && self.kernel_factors.is_empty() && self.ratios.is_empty()
    }

    /// Measured compression ratio for `label`, if the trace recorded
    /// one.
    pub fn ratio_for(&self, label: &str) -> Option<f64> {
        self.ratios.iter().find(|(k, _)| k == label).map(|(_, r)| *r)
    }

    /// Graft the fitted parameters onto `base`: fitted tiers replace
    /// the corresponding `links` entries, kernel factors install as
    /// per-codec overrides, and everything the trace never exercised
    /// keeps its nameplate value.
    pub fn apply(&self, base: &CostModel) -> CostModel {
        let mut links = base.links.clone();
        for (&tier, link) in &self.links {
            if tier < links.len() {
                links[tier] = *link;
            }
        }
        CostModel::new(base.gpu, links, base.cpr_ratio)
            .with_kernel_factors(self.kernel_factors.clone())
    }
}

impl std::fmt::Display for Calibration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "calibration: {} wire samples, {} kernel samples",
            self.wire_samples, self.kernel_samples
        )?;
        for (tier, l) in &self.links {
            writeln!(
                f,
                "  tier {tier}: alpha {:.3e} s | beta {:.3e} B/s",
                l.alpha, l.beta
            )?;
        }
        for (label, k) in &self.kernel_factors {
            writeln!(f, "  kernel factor {label}: {k:.3}")?;
        }
        for (label, r) in &self.ratios {
            writeln!(f, "  measured ratio {label}: {r:.2}x")?;
        }
        Ok(())
    }
}

fn parse_f64(s: Option<&str>) -> Option<f64> {
    s.and_then(|v| v.parse::<f64>().ok())
}

/// Least-squares `secs = alpha + bytes / beta` over one tier's
/// samples. Needs at least two distinct byte sizes for the affine fit;
/// otherwise falls back to a bandwidth-only fit that keeps the base
/// link's latency term.
fn fit_link(samples: &[WireSample], base: &LinkModel) -> Option<LinkModel> {
    if samples.is_empty() {
        return None;
    }
    let n = samples.len() as f64;
    let (mut sx, mut sy) = (0.0, 0.0);
    for s in samples {
        sx += s.bytes;
        sy += s.secs;
    }
    let (mx, my) = (sx / n, sy / n);
    let (mut sxx, mut sxy) = (0.0, 0.0);
    for s in samples {
        sxx += (s.bytes - mx) * (s.bytes - mx);
        sxy += (s.bytes - mx) * (s.secs - my);
    }
    // Affine fit when the sizes actually vary and the slope is
    // physical (time grows with bytes).
    if sxx > 0.0 && sxy > 0.0 {
        let slope = sxy / sxx;
        let alpha = (my - slope * mx).max(0.0);
        return Some(LinkModel::new(alpha, 1.0 / slope));
    }
    // Bandwidth-only: keep the base latency (clamped so no sample
    // implies negative serialization time) and fit beta to the mean.
    let min_secs = samples.iter().fold(f64::INFINITY, |a, s| a.min(s.secs));
    let alpha = base.alpha.min(min_secs * 0.5);
    let ser: f64 = samples.iter().map(|s| s.secs - alpha).sum();
    if ser <= 0.0 || sx <= 0.0 {
        return None;
    }
    Some(LinkModel::new(alpha, sx / ser))
}

/// Map `(track, leg)` to the codec label recorded on the leg span, so
/// kernel samples can be grouped per codec.
fn leg_codecs(run: &TraceRun) -> BTreeMap<(usize, u32), String> {
    let mut out = BTreeMap::new();
    for (&id, t) in &run.tracks {
        for s in &t.spans {
            if s.cat == SpanCat::Leg {
                if let (Some(leg), Some(codec)) = (s.leg, s.arg("codec")) {
                    out.insert((id, leg), codec.to_string());
                }
            }
        }
    }
    out
}

/// True for the device-side codec kernel spans the factor fit consumes.
fn is_codec_kernel(s: &SpanRec) -> bool {
    matches!(s.lane, Lane::Gpu(_))
        && matches!(s.name, "compress" | "compress-batch" | "decompress")
}

/// Fit a [`Calibration`] from `run` against the nameplate `gpu` kernel
/// models and `base_links` (`ClusterSpec::tier_links` order).
pub fn calibrate(run: &TraceRun, gpu: &GpuModel, base_links: &[LinkModel]) -> Calibration {
    let mut wire: BTreeMap<usize, Vec<WireSample>> = BTreeMap::new();
    // Per codec label: Σ base·obs and Σ base² for the through-origin
    // scale fit, pooled over compress + decompress.
    let mut kfit: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    let codecs = leg_codecs(run);
    let mut wire_samples = 0usize;
    let mut kernel_samples = 0usize;

    for (&id, t) in &run.tracks {
        for s in &t.spans {
            if s.cat == SpanCat::Net && s.name == "wire" {
                let (Some(bytes), Some(tier)) = (
                    parse_f64(s.arg("bytes")),
                    s.arg("tier").and_then(|v| v.parse::<usize>().ok()),
                ) else {
                    continue;
                };
                let queue = parse_f64(s.arg("queue_s")).unwrap_or(0.0);
                let secs = s.dur - queue;
                if bytes > 0.0 && secs > 0.0 {
                    wire.entry(tier).or_default().push(WireSample { bytes, secs });
                    wire_samples += 1;
                }
            } else if is_codec_kernel(s) {
                let Some(bytes) = s.arg("bytes").and_then(|v| v.parse::<usize>().ok()) else {
                    continue;
                };
                let Some(label) = s.leg.and_then(|l| codecs.get(&(id, l))) else {
                    continue;
                };
                let base = if s.name == "decompress" {
                    gpu.decompress.time(bytes)
                } else if let Some(k) = s.arg("streams").and_then(|v| v.parse::<usize>().ok()) {
                    gpu.compress.time_multistream(bytes, k, gpu.stream_issue)
                } else {
                    gpu.compress.time(bytes)
                };
                if base > 0.0 && s.dur > 0.0 {
                    let e = kfit.entry(label.clone()).or_insert((0.0, 0.0));
                    e.0 += base * s.dur;
                    e.1 += base * base;
                    kernel_samples += 1;
                }
            }
        }
    }

    let mut links = BTreeMap::new();
    for (tier, samples) in &wire {
        let base = base_links
            .get((*tier).min(base_links.len().saturating_sub(1)))
            .copied()
            .unwrap_or_else(|| LinkModel::new(1e-6, 1e9));
        if let Some(l) = fit_link(samples, &base) {
            links.insert(*tier, l);
        }
    }

    let kernel_factors: Vec<(String, f64)> = kfit
        .into_iter()
        .filter(|(_, (num, den))| *den > 0.0 && *num > 0.0)
        .map(|(label, (num, den))| (label, num / den))
        .collect();

    // Measured wire ratio per codec from the byte counters the codec
    // pipeline leaves behind.
    let reg = run.metrics_registry();
    let mut ratios = Vec::new();
    for key in reg.entries.keys() {
        if let Some(label) = key.strip_prefix("cpr_in_bytes.") {
            let inb = reg.counter(key);
            let outb = reg.counter(&format!("cpr_out_bytes.{label}"));
            if inb > 0.0 && outb > 0.0 {
                ratios.push((label.to_string(), (inb / outb).max(1.0)));
            }
        }
    }

    Calibration {
        links,
        kernel_factors,
        ratios,
        wire_samples,
        kernel_samples,
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::{TrackBuf, Tracer};
    use super::*;
    use crate::sim::Phase;

    /// Build a run whose wire spans follow `secs = alpha + bytes/beta`
    /// exactly on tier 2, whose compress kernels run at twice the
    /// nameplate time, and whose codec counters record a 10x ratio.
    fn synthetic_run(alpha: f64, beta: f64) -> Arc<TraceRun> {
        let gpu = GpuModel::a100();
        let tracer = Tracer::new();
        let mut buf = TrackBuf::new(0);
        buf.open_root("rank0", 0.0);
        buf.open_leg(0, 0.0, vec![("codec", "testcodec".into())]);
        let mut t = 0.0;
        for &bytes in &[1usize << 16, 1 << 18, 1 << 20] {
            let secs = alpha + bytes as f64 / beta;
            buf.span_args(
                "wire",
                SpanCat::Net,
                Lane::Net,
                t,
                secs + 3e-5,
                None,
                vec![
                    ("bytes", format!("{bytes}")),
                    ("tier", "2".into()),
                    ("queue_s", format!("{:e}", 3e-5)),
                ],
            );
            let kdur = 2.0 * gpu.compress.time(bytes);
            buf.span_args(
                "compress",
                SpanCat::Phase,
                Lane::Gpu(0),
                t,
                kdur,
                Some(Phase::Cpr),
                vec![("bytes", format!("{bytes}"))],
            );
            t += secs + kdur;
        }
        buf.counter_add("cpr_in_bytes.testcodec", 1e6);
        buf.counter_add("cpr_out_bytes.testcodec", 1e5);
        buf.close_all(t);
        tracer.sink(buf);
        tracer.take_run(vec![])
    }

    #[test]
    fn link_fit_recovers_the_generating_line() {
        let (alpha, beta) = (8e-6, 12.5e9);
        let run = synthetic_run(alpha, beta);
        let gpu = GpuModel::a100();
        let base = vec![LinkModel::new(1e-6, 300e9); 4];
        let cal = calibrate(&run, &gpu, &base);
        assert_eq!(cal.wire_samples, 3);
        let l = cal.links.get(&2).expect("tier 2 fitted");
        assert!((l.alpha - alpha).abs() < 1e-9, "alpha {} vs {alpha}", l.alpha);
        assert!((l.beta - beta).abs() / beta < 1e-6, "beta {} vs {beta}", l.beta);
        // Untouched tiers keep the nameplate link through apply().
        let cost = cal.apply(&CostModel::new(gpu, base, 10.0));
        assert!((cost.link(2).beta - beta).abs() / beta < 1e-6);
        assert_eq!(cost.link(1).beta, 300e9);
    }

    #[test]
    fn kernel_factor_and_ratio_come_from_the_samples() {
        let run = synthetic_run(8e-6, 12.5e9);
        let gpu = GpuModel::a100();
        let cal = calibrate(&run, &gpu, &[LinkModel::new(1e-6, 300e9); 4]);
        assert_eq!(cal.kernel_samples, 3);
        let (label, factor) = cal
            .kernel_factors
            .first()
            .expect("compress kernels fitted a factor");
        assert_eq!(label, "testcodec");
        assert!((factor - 2.0).abs() < 1e-9, "factor {factor}");
        assert_eq!(cal.ratio_for("testcodec"), Some(10.0));
        assert!(!cal.is_empty());
        assert!(format!("{cal}").contains("kernel factor testcodec"));
    }

    #[test]
    fn single_size_tier_falls_back_to_bandwidth_only() {
        let tracer = Tracer::new();
        let mut buf = TrackBuf::new(0);
        buf.open_root("rank0", 0.0);
        for i in 0..3 {
            buf.span_args(
                "wire",
                SpanCat::Net,
                Lane::Net,
                i as f64 * 1e-3,
                1e-6 + 65536.0 / 50e9,
                None,
                vec![("bytes", "65536".into()), ("tier", "1".into())],
            );
        }
        buf.close_all(1.0);
        tracer.sink(buf);
        let run = tracer.take_run(vec![]);
        let cal = calibrate(&run, &GpuModel::a100(), &[LinkModel::new(1e-6, 300e9); 2]);
        let l = cal.links.get(&1).expect("bandwidth-only fit");
        // Base latency retained; beta explains the rest of the time.
        assert!((l.alpha - 1e-6).abs() < 1e-12);
        let predicted = l.alpha + 65536.0 / l.beta;
        assert!((predicted - (1e-6 + 65536.0 / 50e9)).abs() < 1e-12);
    }
}
