//! Trace analytics: critical-path extraction, bottleneck attribution,
//! per-leg slack and prediction residuals over a [`TraceRun`].
//!
//! The paper's Fig. 2 diagnosis — GPUs idling behind serialized
//! compression and links starved behind stragglers — is only
//! actionable once a recorded run can say *which* chain of work set
//! the makespan. This module walks the span forest backwards from the
//! last-finishing rank, hopping cross-rank message edges (recovered
//! from the sender-side `wire` net spans and the receiver's annotated
//! `recv-wait` spans), and produces a chain of segments that tile
//! `[0, makespan]` exactly: the critical path's total is
//! `last.end − first.start`, which reproduces the root makespan
//! **bit-for-bit** by construction, never as a rounded sum of parts.
//!
//! Each segment is attributed to one of four categories — kernel
//! (device kernels and PCIe staging), wire (fabric transfer), queue
//! (shared-stage fabric waits within a wire hop) and host (API calls,
//! syncs, idle) — rolled up per crossing tier and per codec stage in a
//! [`BottleneckReport`], alongside per-`(leg, rank)` slack (how much
//! later a rank's leg could have finished without moving the global
//! leg end) and stragglers (ranks whose leg ran long against the
//! median). When the dispatch recorded per-leg cost-model predictions
//! (the `pred_legs` annotation on the tuner-decision instant),
//! observed-vs-predicted residuals ride along — the raw material
//! [`super::calibrate`] fits its calibrated model from.

use std::collections::BTreeMap;
use std::fmt;

use crate::sim::Phase;

use super::{Lane, SpanCat, SpanRec, TraceRun, TrackBuf};

/// Ranks whose leg duration exceeds the median by this factor are
/// flagged as stragglers.
pub const STRAGGLER_FACTOR: f64 = 1.05;

/// Bottleneck category of one critical-path segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    /// Device work: compression / reduction kernels and PCIe staging.
    Kernel,
    /// Fabric transfer time of an in-flight message.
    Wire,
    /// Queue waits at shared fabric stages (NIC, oversubscribed
    /// uplinks) inside a wire hop.
    Queue,
    /// Host API calls, synchronization, and idle gaps with no device
    /// or network work behind them.
    Host,
}

impl Category {
    /// Stable lowercase label (export / digest key).
    pub fn label(self) -> &'static str {
        match self {
            Category::Kernel => "kernel",
            Category::Wire => "wire",
            Category::Queue => "queue",
            Category::Host => "host",
        }
    }
}

/// One segment of the critical path: `[start, end]` on `track`,
/// attributed to `label` / `category`. Consecutive segments share
/// their boundary timestamps exactly (same f64 bits), so the chain
/// tiles `[0, makespan]` without gaps or overlaps.
#[derive(Debug, Clone)]
pub struct PathSeg {
    /// Track (rank / actor) the segment's work ran on. For wire
    /// segments: the *sending* track.
    pub track: usize,
    /// Segment start, virtual seconds.
    pub start: f64,
    /// Segment end, virtual seconds.
    pub end: f64,
    /// Schedule leg active over the segment, when known.
    pub leg: Option<u32>,
    /// Span name the interval is attributed to (`compress`,
    /// `recv-wait`, `wire`, `idle`, ...).
    pub label: String,
    /// Bottleneck category.
    pub category: Category,
    /// Crossing tier of wire segments (`DeliverPath::lca`).
    pub tier: Option<usize>,
    /// Queue-wait share of a wire segment (seconds spent at shared
    /// fabric stages; attributed to [`Category::Queue`] in rollups).
    pub queue_s: f64,
}

impl PathSeg {
    /// Segment length, seconds.
    pub fn dur(&self) -> f64 {
        self.end - self.start
    }
}

/// The extracted critical path: time-ordered contiguous segments.
#[derive(Debug, Clone, Default)]
pub struct CriticalPath {
    /// Segments ascending in time; `segments[i].end` equals
    /// `segments[i+1].start` bit-exactly.
    pub segments: Vec<PathSeg>,
}

impl CriticalPath {
    /// Total path length: `last.end − first.start`. Because the chain
    /// tiles `[0, makespan]` with shared boundaries, this equals the
    /// run's root makespan bit-for-bit (asserted by the test suite),
    /// not merely up to accumulated rounding.
    pub fn total_s(&self) -> f64 {
        match (self.segments.first(), self.segments.last()) {
            (Some(a), Some(b)) => b.end - a.start,
            _ => 0.0,
        }
    }

    /// Canonical textual digest (track, leg, category, label and
    /// bit-exact boundaries per segment) — equal across execution
    /// backends exactly when the analyses agree.
    pub fn digest(&self) -> String {
        let mut out = String::new();
        for s in &self.segments {
            use fmt::Write;
            let _ = writeln!(
                out,
                "{}|{}|{}|{}|{:016x}|{:016x}|{}",
                s.track,
                s.leg.map_or(-1i64, |l| l as i64),
                s.category.label(),
                s.label,
                s.start.to_bits(),
                s.end.to_bits(),
                s.tier.map_or(-1i64, |t| t as i64),
            );
        }
        out
    }
}

/// A rank whose leg ran long against the cross-rank median.
#[derive(Debug, Clone)]
pub struct Straggler {
    /// Schedule leg index.
    pub leg: u32,
    /// Offending track.
    pub track: usize,
    /// This track's leg duration, seconds.
    pub dur_s: f64,
    /// Median leg duration across tracks, seconds.
    pub median_s: f64,
}

/// Slack of one `(leg, track)`: how much later this rank's leg could
/// have ended without moving the leg's global completion. Zero on the
/// chain that sets the leg's end; non-negative everywhere by
/// construction.
#[derive(Debug, Clone)]
pub struct LegSlack {
    /// Schedule leg index.
    pub leg: u32,
    /// Track the slack belongs to.
    pub track: usize,
    /// Slack, seconds (`max_end(leg) − end(leg, track)`).
    pub slack_s: f64,
}

/// Attribution rollup over the critical path.
#[derive(Debug, Clone, Default)]
pub struct BottleneckReport {
    /// Seconds per category, fixed order kernel / wire / queue / host.
    /// Sums to the critical-path total (wire segments contribute their
    /// queue share to `Queue` and the remainder to `Wire`).
    pub by_category: Vec<(Category, f64)>,
    /// Network seconds (wire + queue) per crossing tier.
    pub by_tier: BTreeMap<usize, f64>,
    /// Kernel seconds per codec stage (staged pipelines split their
    /// kernels; unstaged kernel time keys on the kernel name).
    pub by_stage: BTreeMap<String, f64>,
    /// Ranks whose leg duration exceeded the median by
    /// [`STRAGGLER_FACTOR`].
    pub stragglers: Vec<Straggler>,
}

impl BottleneckReport {
    /// Seconds attributed to `cat`.
    pub fn category_s(&self, cat: Category) -> f64 {
        self.by_category
            .iter()
            .find(|(c, _)| *c == cat)
            .map_or(0.0, |(_, s)| *s)
    }

    /// The dominant category and its share of `total_s`.
    pub fn dominant(&self, total_s: f64) -> Option<(Category, f64)> {
        self.by_category
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, s)| (*c, if total_s > 0.0 { s / total_s } else { 0.0 }))
    }
}

/// Observed-vs-predicted timing of one schedule leg.
#[derive(Debug, Clone)]
pub struct LegResidual {
    /// Schedule leg index.
    pub leg: usize,
    /// Cost-model prediction captured at plan time, seconds.
    pub predicted_s: f64,
    /// Max observed leg-span duration across ranks, seconds.
    pub observed_s: f64,
}

impl LegResidual {
    /// Signed relative residual `(observed − predicted) / predicted`.
    pub fn relative(&self) -> f64 {
        if self.predicted_s > 0.0 {
            (self.observed_s - self.predicted_s) / self.predicted_s
        } else {
            0.0
        }
    }
}

/// Full analysis of one traced run.
#[derive(Debug, Clone, Default)]
pub struct TraceAnalysis {
    /// The run's makespan (max root-span end), seconds.
    pub makespan_s: f64,
    /// The extracted critical path.
    pub critical_path: CriticalPath,
    /// Attribution rollups and stragglers.
    pub bottlenecks: BottleneckReport,
    /// Per-`(leg, track)` slack, all entries non-negative.
    pub slacks: Vec<LegSlack>,
    /// Per-leg prediction residuals (empty when the dispatch recorded
    /// no `pred_legs` annotation — e.g. flat algorithms or imports of
    /// pre-analytics traces).
    pub residuals: Vec<LegResidual>,
}

impl TraceAnalysis {
    /// Largest `|relative residual|` across legs (`None` without
    /// predictions).
    pub fn max_relative_residual(&self) -> Option<f64> {
        self.residuals
            .iter()
            .map(|r| r.relative().abs())
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Stable digest of the critical path (backend-equivalence tests).
    pub fn digest(&self) -> String {
        self.critical_path.digest()
    }
}

impl fmt::Display for TraceAnalysis {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let total = self.critical_path.total_s();
        writeln!(
            f,
            "critical path: {} segments, {:.6e}s (makespan {:.6e}s)",
            self.critical_path.segments.len(),
            total,
            self.makespan_s
        )?;
        let pct = |s: f64| if total > 0.0 { 100.0 * s / total } else { 0.0 };
        let cats: Vec<String> = self
            .bottlenecks
            .by_category
            .iter()
            .map(|(c, s)| format!("{} {:.1}%", c.label(), pct(*s)))
            .collect();
        writeln!(f, "  by category: {}", cats.join(" | "))?;
        if !self.bottlenecks.by_tier.is_empty() {
            let tiers: Vec<String> = self
                .bottlenecks
                .by_tier
                .iter()
                .map(|(t, s)| format!("t{t} {:.1}%", pct(*s)))
                .collect();
            writeln!(f, "  network by tier: {}", tiers.join(" | "))?;
        }
        if !self.bottlenecks.by_stage.is_empty() {
            let mut stages: Vec<(&String, &f64)> = self.bottlenecks.by_stage.iter().collect();
            stages.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
            let top: Vec<String> = stages
                .iter()
                .take(4)
                .map(|(k, s)| format!("{k} {:.1}%", pct(**s)))
                .collect();
            writeln!(f, "  kernel by stage: {}", top.join(" | "))?;
        }
        let mut longest: Vec<&PathSeg> = self.critical_path.segments.iter().collect();
        longest.sort_by(|a, b| b.dur().partial_cmp(&a.dur()).unwrap());
        for s in longest.iter().take(5) {
            writeln!(
                f,
                "  seg {:>9.3e}s  {:6}  track {:>4}  leg {:>2}  [{}]",
                s.dur(),
                s.category.label(),
                s.track,
                s.leg.map_or(-1i64, |l| l as i64),
                s.label
            )?;
        }
        if self.bottlenecks.stragglers.is_empty() {
            writeln!(f, "  stragglers: none")?;
        } else {
            for st in self.bottlenecks.stragglers.iter().take(5) {
                writeln!(
                    f,
                    "  straggler: leg {} track {} ran {:.3e}s ({:.2}x median)",
                    st.leg,
                    st.track,
                    st.dur_s,
                    st.dur_s / st.median_s.max(f64::MIN_POSITIVE)
                )?;
            }
        }
        if self.residuals.is_empty() {
            write!(f, "  residuals: no per-leg predictions recorded")?;
        } else {
            write!(f, "  residuals (observed vs predicted):")?;
            for r in &self.residuals {
                write!(
                    f,
                    "\n    leg {}: pred {:.3e}s obs {:.3e}s ({:+.1}%)",
                    r.leg,
                    r.predicted_s,
                    r.observed_s,
                    100.0 * r.relative()
                )?;
            }
        }
        Ok(())
    }
}

/// One flattened interval of a track's host timeline, owned by the
/// deepest host-lane span active over it (`None` only before the root
/// opens — never inside a well-formed track).
struct Piece<'a> {
    start: f64,
    end: f64,
    owner: Option<&'a SpanRec>,
}

/// Flatten a track's host-lane spans (a call stack per
/// `check_well_formed`) into contiguous pieces with exact shared
/// boundaries, each attributed to the deepest enclosing span.
fn flatten_host(track: &TrackBuf) -> Vec<Piece<'_>> {
    let mut host: Vec<&SpanRec> = track
        .spans
        .iter()
        .filter(|s| s.lane == Lane::Host && s.dur > 0.0)
        .collect();
    // Parents before children: start ascending, end descending; ties
    // keep emission order (stable sort), so the deeper span — emitted
    // later — sits on top of the stack and owns the piece.
    host.sort_by(|a, b| {
        a.start
            .partial_cmp(&b.start)
            .unwrap()
            .then(b.end().partial_cmp(&a.end()).unwrap())
    });
    let mut pieces = Vec::new();
    let Some(first) = host.first() else {
        return pieces;
    };
    let mut cursor = first.start;
    let mut stack: Vec<&SpanRec> = Vec::new();
    for &s in &host {
        while let Some(&top) = stack.last() {
            if top.end() <= s.start {
                if cursor < top.end() {
                    pieces.push(Piece {
                        start: cursor,
                        end: top.end(),
                        owner: Some(top),
                    });
                    cursor = top.end();
                }
                stack.pop();
            } else {
                break;
            }
        }
        if cursor < s.start {
            pieces.push(Piece {
                start: cursor,
                end: s.start,
                owner: stack.last().copied(),
            });
            cursor = s.start;
        }
        stack.push(s);
    }
    while let Some(top) = stack.pop() {
        if cursor < top.end() {
            pieces.push(Piece {
                start: cursor,
                end: top.end(),
                owner: Some(top),
            });
            cursor = top.end();
        }
    }
    pieces
}

/// A sender-side message edge recovered from a `wire` net span.
struct WireEdge {
    src_track: usize,
    depart: f64,
    queue_s: f64,
    tier: usize,
    leg: Option<u32>,
}

fn arg<'a>(s: &'a SpanRec, key: &str) -> Option<&'a str> {
    s.arg(key)
}

/// Index every sender-side `wire` span by
/// `(dst_track, arrival_bits, src_track)`. When two messages between
/// the same pair arrive at the identical instant, the earlier
/// departure (the longer, more constraining flight) wins.
fn wire_edges(run: &TraceRun) -> BTreeMap<(usize, u64, usize), WireEdge> {
    let mut edges: BTreeMap<(usize, u64, usize), WireEdge> = BTreeMap::new();
    for (&id, t) in &run.tracks {
        for s in &t.spans {
            if s.lane != Lane::Net || s.name != "wire" {
                continue;
            }
            let (Some(dst), Some(bits)) = (arg(s, "dst"), arg(s, "arrival")) else {
                continue;
            };
            let (Ok(dst), Ok(bits)) = (dst.parse::<usize>(), u64::from_str_radix(bits, 16))
            else {
                continue;
            };
            let edge = WireEdge {
                src_track: id,
                depart: s.start,
                queue_s: arg(s, "queue_s").and_then(|v| v.parse().ok()).unwrap_or(0.0),
                tier: arg(s, "tier").and_then(|v| v.parse().ok()).unwrap_or(0),
                leg: s.leg,
            };
            let key = (dst, bits, id);
            match edges.get(&key) {
                Some(e) if e.depart <= edge.depart => {}
                _ => {
                    edges.insert(key, edge);
                }
            }
        }
    }
    edges
}

/// Classify an uncharged host gap `[a, b)` by the device-lane work
/// overlapping it: GPU kernels and PCIe copies make it kernel time
/// (the host is blocked draining the device); nothing running makes
/// it idle host time.
fn classify_gap(track: &TrackBuf, a: f64, b: f64) -> (Category, String) {
    let mut best = 0.0;
    let mut label: Option<&str> = None;
    for s in &track.spans {
        if matches!(s.lane, Lane::Host | Lane::Net) || s.cat == SpanCat::Codec {
            continue;
        }
        let ov = s.end().min(b) - s.start.max(a);
        if ov > best {
            best = ov;
            label = Some(&s.name);
        }
    }
    match label {
        Some(name) => (Category::Kernel, name.to_string()),
        None => (Category::Host, "idle".to_string()),
    }
}

/// Classify a piece by its owning span's charge.
fn classify_piece(track: &TrackBuf, p: &Piece<'_>, end: f64) -> (Category, String) {
    match p.owner {
        Some(s) => match s.charge {
            Some(Phase::Cpr) | Some(Phase::Redu) | Some(Phase::DataMove) => {
                (Category::Kernel, s.name.clone())
            }
            Some(Phase::Comm) => (Category::Wire, s.name.clone()),
            Some(Phase::Other) => (Category::Host, s.name.clone()),
            // Container span (root / leg): an uncharged wait.
            None => classify_gap(track, p.start, end),
        },
        None => (Category::Host, "idle".to_string()),
    }
}

/// Walk the critical path backwards from the last-finishing track.
fn extract_path(run: &TraceRun) -> CriticalPath {
    let pieces: BTreeMap<usize, Vec<Piece<'_>>> =
        run.tracks.iter().map(|(&id, t)| (id, flatten_host(t))).collect();
    let edges = wire_edges(run);
    // Finishing track: max root end, ties to the lowest id.
    let Some((&start_track, _)) = run
        .tracks
        .iter()
        .max_by(|a, b| a.1.root_end().partial_cmp(&b.1.root_end()).unwrap().then(b.0.cmp(a.0)))
    else {
        return CriticalPath::default();
    };
    let mut track = start_track;
    let mut t = run.tracks[&track].root_end();
    let mut segs: Vec<PathSeg> = Vec::new();
    // Every step strictly decreases `t`; the guard only trips on a
    // malformed (e.g. hand-edited) trace.
    let guard = run.span_count() * 4 + 64;
    while t > 0.0 && segs.len() < guard {
        let Some(ps) = pieces.get(&track) else { break };
        let idx = ps.partition_point(|p| p.start < t);
        if idx == 0 {
            break;
        }
        let p = &ps[idx - 1];
        // A recv-wait piece whose end we reached exactly is a message
        // arrival: hop to the sender's departure.
        let jump = if p.owner.is_some_and(|s| s.name == "recv-wait") && p.end == t {
            let s = p.owner.expect("checked");
            let src = arg(s, "src").and_then(|v| v.parse::<usize>().ok());
            let bits = arg(s, "arrival").and_then(|v| u64::from_str_radix(v, 16).ok());
            match (src, bits) {
                (Some(src), Some(bits)) => {
                    edges.get(&(track, bits, src)).filter(|e| e.depart < t)
                }
                _ => None,
            }
        } else {
            None
        };
        if let Some(e) = jump {
            segs.push(PathSeg {
                track: e.src_track,
                start: e.depart,
                end: t,
                leg: e.leg.or_else(|| p.owner.and_then(|s| s.leg)),
                label: "wire".to_string(),
                category: Category::Wire,
                tier: Some(e.tier),
                queue_s: e.queue_s.min(t - e.depart),
            });
            track = e.src_track;
            t = e.depart;
        } else {
            let tb = &run.tracks[&track];
            let (category, label) = classify_piece(tb, p, t.min(p.end));
            segs.push(PathSeg {
                track,
                start: p.start,
                end: t,
                leg: p.owner.and_then(|s| s.leg),
                label,
                category,
                tier: None,
                queue_s: 0.0,
            });
            t = p.start;
        }
    }
    segs.reverse();
    CriticalPath { segments: segs }
}

/// Roll critical-path segments up into the attribution report.
fn attribute(run: &TraceRun, path: &CriticalPath) -> BottleneckReport {
    let mut kernel = 0.0;
    let mut wire = 0.0;
    let mut queue = 0.0;
    let mut host = 0.0;
    let mut by_tier: BTreeMap<usize, f64> = BTreeMap::new();
    let mut by_stage: BTreeMap<String, f64> = BTreeMap::new();
    for s in &path.segments {
        match s.category {
            Category::Kernel => {
                kernel += s.dur();
                // Apportion staged-codec kernels onto their pipeline
                // stages; anything uncovered keys on the kernel name.
                let mut covered = 0.0;
                if let Some(tb) = run.tracks.get(&s.track) {
                    for c in &tb.spans {
                        if c.cat != SpanCat::Codec {
                            continue;
                        }
                        let ov = c.end().min(s.end) - c.start.max(s.start);
                        if ov > 0.0 {
                            *by_stage.entry(c.name.clone()).or_insert(0.0) += ov;
                            covered += ov;
                        }
                    }
                }
                let rest = s.dur() - covered;
                if rest > 0.0 {
                    *by_stage.entry(s.label.clone()).or_insert(0.0) += rest;
                }
            }
            Category::Wire => {
                wire += s.dur() - s.queue_s;
                queue += s.queue_s;
                if let Some(t) = s.tier {
                    *by_tier.entry(t).or_insert(0.0) += s.dur();
                }
            }
            Category::Queue => queue += s.dur(),
            Category::Host => host += s.dur(),
        }
    }
    BottleneckReport {
        by_category: vec![
            (Category::Kernel, kernel),
            (Category::Wire, wire),
            (Category::Queue, queue),
            (Category::Host, host),
        ],
        by_tier,
        by_stage,
        stragglers: stragglers(run),
    }
}

/// Per-leg `(end, dur)` samples across tracks.
fn leg_spans(run: &TraceRun) -> BTreeMap<u32, Vec<(usize, f64, f64)>> {
    let mut legs: BTreeMap<u32, Vec<(usize, f64, f64)>> = BTreeMap::new();
    for (&id, t) in &run.tracks {
        for s in &t.spans {
            if s.cat == SpanCat::Leg {
                if let Some(l) = s.leg {
                    legs.entry(l).or_default().push((id, s.end(), s.dur));
                }
            }
        }
    }
    legs
}

fn stragglers(run: &TraceRun) -> Vec<Straggler> {
    let mut out = Vec::new();
    for (leg, rows) in leg_spans(run) {
        let mut durs: Vec<f64> = rows.iter().map(|(_, _, d)| *d).collect();
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = durs[durs.len() / 2];
        if median <= 0.0 {
            continue;
        }
        for (track, _, dur) in rows {
            if dur > median * STRAGGLER_FACTOR {
                out.push(Straggler {
                    leg,
                    track,
                    dur_s: dur,
                    median_s: median,
                });
            }
        }
    }
    out
}

fn slacks(run: &TraceRun) -> Vec<LegSlack> {
    let mut out = Vec::new();
    for (leg, rows) in leg_spans(run) {
        let max_end = rows.iter().map(|(_, e, _)| *e).fold(0.0, f64::max);
        for (track, end, _) in rows {
            out.push(LegSlack {
                leg,
                track,
                slack_s: max_end - end,
            });
        }
    }
    out
}

/// Join observed per-leg durations against the per-leg predictions the
/// dispatcher annotated onto its decision instant (`pred_legs`, `+`
/// separated seconds in leg order).
fn residuals(run: &TraceRun) -> Vec<LegResidual> {
    let preds: Option<Vec<f64>> = run
        .instants
        .iter()
        .chain(run.tracks.values().flat_map(|t| t.instants.iter()))
        .find_map(|i| i.args.iter().find(|(k, _)| *k == "pred_legs").map(|(_, v)| v))
        .map(|v| v.split('+').filter_map(|p| p.parse().ok()).collect());
    let Some(preds) = preds else {
        return Vec::new();
    };
    let legs = leg_spans(run);
    preds
        .iter()
        .enumerate()
        .map(|(i, &pred)| LegResidual {
            leg: i,
            predicted_s: pred,
            observed_s: legs
                .get(&(i as u32))
                .map_or(0.0, |rows| rows.iter().map(|(_, _, d)| *d).fold(0.0, f64::max)),
        })
        .collect()
}

/// Analyze one traced run: extract the critical path, attribute its
/// segments, compute per-leg slack and stragglers, and join prediction
/// residuals.
pub fn analyze(run: &TraceRun) -> TraceAnalysis {
    let critical_path = extract_path(run);
    let bottlenecks = attribute(run, &critical_path);
    TraceAnalysis {
        makespan_s: run.root_end(),
        critical_path,
        bottlenecks,
        slacks: slacks(run),
        residuals: residuals(run),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;

    /// Two ranks: rank 0 compresses and sends at t=4 (arriving t=7
    /// after 1s of uplink queueing), rank 1 waits on the message, then
    /// reduces until t=10. The chain must hop the message edge.
    fn synthetic_run() -> std::sync::Arc<TraceRun> {
        let bits = |v: f64| format!("{:016x}", v.to_bits());
        let mut s = TrackBuf::new(0);
        s.open_root("collective", 0.0);
        s.open_leg(0, 0.0, vec![]);
        s.span("issue", SpanCat::Phase, Lane::Host, 0.0, 1.0, Some(Phase::Other));
        s.span("compress", SpanCat::Phase, Lane::Gpu(0), 0.0, 4.0, Some(Phase::Cpr));
        s.span_args(
            "wire",
            SpanCat::Net,
            Lane::Net,
            4.0,
            3.0,
            None,
            vec![
                ("dst", "1".into()),
                ("arrival", bits(7.0)),
                ("queue_s", "1.0".into()),
                ("tier", "2".into()),
            ],
        );
        s.close_all(9.5);

        let mut r = TrackBuf::new(1);
        r.open_root("collective", 0.0);
        r.open_leg(0, 0.0, vec![]);
        r.span_args(
            "recv-wait",
            SpanCat::Phase,
            Lane::Host,
            0.0,
            7.0,
            Some(Phase::Comm),
            vec![("src", "0".into()), ("arrival", bits(7.0))],
        );
        r.span("issue", SpanCat::Phase, Lane::Host, 7.0, 1.0, Some(Phase::Other));
        r.span("reduce", SpanCat::Phase, Lane::Gpu(0), 7.0, 3.0, Some(Phase::Redu));
        r.close_all(10.0);

        let tr = Tracer::new();
        tr.sink(s);
        tr.sink(r);
        tr.take_run(vec![])
    }

    #[test]
    fn critical_path_hops_the_message_edge_and_tiles_exactly() {
        let run = synthetic_run();
        let a = analyze(&run);
        assert_eq!(a.makespan_s, 10.0);
        // Bit-exact tiling: total == makespan, segments contiguous.
        assert_eq!(a.critical_path.total_s(), run.root_end());
        for w in a.critical_path.segments.windows(2) {
            assert_eq!(w[0].end.to_bits(), w[1].start.to_bits());
        }
        let labels: Vec<&str> =
            a.critical_path.segments.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, ["issue", "compress", "wire", "issue", "reduce"]);
        // The wire hop runs on the sender's track and names its tier.
        let wire = &a.critical_path.segments[2];
        assert_eq!((wire.track, wire.tier, wire.queue_s), (0, Some(2), 1.0));
        // Categories sum to the path total.
        let cat_sum: f64 = a.bottlenecks.by_category.iter().map(|(_, s)| s).sum();
        assert!((cat_sum - a.critical_path.total_s()).abs() < 1e-9);
        assert_eq!(a.bottlenecks.category_s(Category::Kernel), 5.0);
        assert_eq!(a.bottlenecks.category_s(Category::Wire), 2.0);
        assert_eq!(a.bottlenecks.category_s(Category::Queue), 1.0);
        assert_eq!(a.bottlenecks.category_s(Category::Host), 2.0);
        assert_eq!(a.bottlenecks.by_tier.get(&2), Some(&3.0));
    }

    #[test]
    fn slack_is_nonnegative_and_zero_on_the_binding_rank() {
        let run = synthetic_run();
        let a = analyze(&run);
        assert!(!a.slacks.is_empty());
        for s in &a.slacks {
            assert!(s.slack_s >= 0.0);
        }
        // Rank 1 sets leg 0's end (t=10); rank 0 closed early at 9.5.
        let r1 = a.slacks.iter().find(|s| s.track == 1).unwrap();
        let r0 = a.slacks.iter().find(|s| s.track == 0).unwrap();
        assert_eq!(r1.slack_s, 0.0);
        assert_eq!(r0.slack_s, 0.5);
    }

    #[test]
    fn stragglers_flag_the_long_leg() {
        let run = synthetic_run();
        // Leg durations 9.5 vs 10.0 — within 5% of the median, so no
        // straggler on the synthetic run.
        assert!(analyze(&run).bottlenecks.stragglers.is_empty());
    }

    #[test]
    fn residuals_join_predictions_when_recorded() {
        let run = synthetic_run();
        assert!(analyze(&run).residuals.is_empty());
        let tr = Tracer::new();
        for t in run.tracks.values() {
            tr.sink(t.clone());
        }
        tr.instant(
            "tuner-decision",
            0.0,
            vec![("pred_legs", "8.0e0".into())],
        );
        let run2 = tr.take_run(vec![]);
        let a = analyze(&run2);
        assert_eq!(a.residuals.len(), 1);
        let r = &a.residuals[0];
        assert_eq!((r.predicted_s, r.observed_s), (8.0, 10.0));
        assert!((r.relative() - 0.25).abs() < 1e-12);
        assert_eq!(a.max_relative_residual(), Some(0.25));
    }
}
