//! Flight recorder: virtual-time span tracing and a metrics registry.
//!
//! gZCCL's central argument (Fig. 2) is that collective time hides
//! underutilized GPUs and serialized compression stages — an argument
//! that only stays checkable at scale if every leg, kernel stage and
//! uplink-queue wait is attributable on a timeline. This module is the
//! one recording contract threaded through the coordinator, engine,
//! executor, fabric, tuner and CLI layers:
//!
//! * A [`Tracer`] is a cheap cloneable handle to a shared sink. Each
//!   rank records into its own [`TrackBuf`] (nested spans: collective →
//!   leg → phase → codec stage; instant events; metric samples) and
//!   flushes it once at [`crate::coordinator::RankCtx::finish`].
//!   Because ranks only ever write their own track, and all span
//!   timestamps are *virtual*, the two execution backends
//!   ([`crate::coordinator::ExecBackend`]) produce bit-identical span
//!   trees — the recording is deterministic by construction.
//! * A [`MetricsRegistry`] aggregates counters / gauges / histograms
//!   across ranks (bytes moved per link class, compression ratio per
//!   codec, uplink queue-wait, Jain fairness per tenant).
//! * [`TraceRun::to_chrome_json`] emits Chrome-trace / Perfetto JSON
//!   with virtual time as the track clock and ranks (or tenant/rank
//!   actors) as tracks; [`MetricsRegistry::to_json`] emits a flat
//!   metrics JSON.
//!
//! **Overhead guarantees.** Tracing is disabled by default
//! (`ClusterSpec::trace == None`): every hook in the hot path is a
//! single `Option` discriminant test. More fundamentally, recording can
//! never perturb *virtual* time — spans observe timestamps that the
//! cost models already produced; they never feed back into a timeline
//! reservation — so makespans are identical traced, untraced, and with
//! the subsystem compiled out.
//!
//! **Accounting invariant.** Every charge against a rank's
//! [`crate::sim::Breakdown`] emits exactly one charged span with the
//! same duration, in the same order, so [`TrackBuf::breakdown`] is
//! bit-for-bit equal to the clock's own phase sums (debug-asserted at
//! flush). Root spans cover `[0, rank_finish]`, so the max root-span
//! end across tracks equals `RunReport::makespan` exactly.

pub mod analysis;
pub mod calibrate;
pub mod export;

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::sim::{Breakdown, Phase};

/// Which simulated engine a span occupies within its track. Lanes map
/// to Chrome trace `tid`s. Only the host lane is strictly nested (the
/// rank clock is monotone); kernel and copy-engine lanes are busy
/// windows that may overlap the host timeline, and copy spans include
/// their engine queue wait (so a queued copy's span can overlap its
/// predecessor's on the same lane).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// The rank's host timeline (the [`crate::sim::RankClock`]).
    Host,
    /// Network waits attributed to this rank's in-flight messages.
    Net,
    /// Host→device copy engine.
    H2d,
    /// Device→host copy engine.
    D2h,
    /// GPU stream `0` = default stream, `1 + i` = non-default `i`.
    Gpu(u32),
}

impl Lane {
    /// Chrome trace `tid` for this lane.
    pub fn tid(self) -> u32 {
        match self {
            Lane::Host => 0,
            Lane::Net => 1,
            Lane::H2d => 2,
            Lane::D2h => 3,
            Lane::Gpu(s) => 4 + s,
        }
    }

    /// Human label for thread-name metadata.
    pub fn label(self) -> String {
        match self {
            Lane::Host => "host".into(),
            Lane::Net => "net".into(),
            Lane::H2d => "h2d".into(),
            Lane::D2h => "d2h".into(),
            Lane::Gpu(0) => "gpu.default".into(),
            Lane::Gpu(s) => format!("gpu.s{}", s - 1),
        }
    }
}

/// Span taxonomy level (Chrome trace `cat`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanCat {
    /// The per-rank root: one span covering the whole collective.
    Collective,
    /// One schedule leg ([`crate::coordinator::RankCtx::begin_leg`]).
    Leg,
    /// One phase charge (CPR / COMM / DATAMOVE / REDU / OTHERS).
    Phase,
    /// A codec pipeline stage within a compression kernel.
    Codec,
    /// A fabric reservation wait (NIC serialization, uplink queue).
    Net,
}

impl SpanCat {
    /// Chrome trace category string.
    pub fn label(self) -> &'static str {
        match self {
            SpanCat::Collective => "collective",
            SpanCat::Leg => "leg",
            SpanCat::Phase => "phase",
            SpanCat::Codec => "codec",
            SpanCat::Net => "net",
        }
    }
}

/// One completed span: `[start, start + dur]` in virtual seconds.
#[derive(Debug, Clone)]
pub struct SpanRec {
    /// Span name (e.g. `compress`, `leg2`, `wait:up-tx.t2`).
    pub name: String,
    /// Taxonomy level.
    pub cat: SpanCat,
    /// Track lane.
    pub lane: Lane,
    /// Start, virtual seconds.
    pub start: f64,
    /// Duration, virtual seconds (`NaN` while still open).
    pub dur: f64,
    /// The [`Breakdown`] phase this span's duration was charged to, or
    /// `None` for structural spans (root, legs, codec stages, waits).
    pub charge: Option<Phase>,
    /// Schedule leg index active when the span was recorded.
    pub leg: Option<u32>,
    /// Extra key/value annotations.
    pub args: Vec<(&'static str, String)>,
}

impl SpanRec {
    /// End timestamp, virtual seconds.
    pub fn end(&self) -> f64 {
        self.start + self.dur
    }

    /// Look up an annotation by key.
    pub fn arg(&self, key: &str) -> Option<&str> {
        self.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
    }
}

/// One instant event (Chrome trace `ph: "i"`): tuner decisions with
/// their rejected alternatives, budget vetoes, adaptive eb relaxations,
/// leg warnings, deadlock diagnostics.
#[derive(Debug, Clone)]
pub struct InstantRec {
    /// Event name (e.g. `tuner-decision`, `budget-veto`, `deadlock`).
    pub name: String,
    /// Virtual timestamp.
    pub t: f64,
    /// Owning track, or `None` for run-global events.
    pub track: Option<usize>,
    /// Key/value detail (e.g. the rejected algorithm candidates).
    pub args: Vec<(&'static str, String)>,
}

/// A metric value in the registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MetricVal {
    /// Monotone sum across ranks (e.g. bytes per link class).
    Counter(f64),
    /// Last-write scalar (e.g. Jain fairness).
    Gauge(f64),
    /// Sample distribution (e.g. uplink queue-wait seconds).
    Hist(HistStat),
}

/// Number of fixed log-spaced histogram buckets.
const HIST_BUCKETS: usize = 64;
/// Bucket grid lower edge, `log10` seconds (1 ns).
const HIST_LOG_MIN: f64 = -9.0;
/// Bucket grid upper edge, `log10` seconds (1000 s).
const HIST_LOG_MAX: f64 = 3.0;

/// Histogram summary statistics (count / sum / min / max) plus a fixed
/// log-spaced bucket array covering 1 ns .. 1000 s of virtual time, so
/// tail quantiles ([`HistStat::p99`]) survive cross-rank aggregation —
/// the queue-wait tail is the straggler signal the trace analyzer
/// keys on. Samples outside the grid clamp to the edge buckets;
/// quantile estimates are exact to within one bucket's width (~1.54×
/// in value) and always clamped into `[min, max]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistStat {
    /// Number of samples.
    pub count: u64,
    /// Sum of samples.
    pub sum: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    buckets: [u64; HIST_BUCKETS],
}

/// Bucket index for sample `v` (non-positive samples take bucket 0).
fn hist_bucket(v: f64) -> usize {
    if v <= 0.0 {
        return 0;
    }
    let w = (HIST_LOG_MAX - HIST_LOG_MIN) / HIST_BUCKETS as f64;
    let i = ((v.log10() - HIST_LOG_MIN) / w).floor();
    (i.max(0.0) as usize).min(HIST_BUCKETS - 1)
}

impl HistStat {
    fn one(v: f64) -> Self {
        let mut buckets = [0u64; HIST_BUCKETS];
        buckets[hist_bucket(v)] = 1;
        HistStat {
            count: 1,
            sum: v,
            min: v,
            max: v,
            buckets,
        }
    }

    fn absorb(&mut self, o: HistStat) {
        self.count += o.count;
        self.sum += o.sum;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
        for (a, b) in self.buckets.iter_mut().zip(o.buckets.iter()) {
            *a += b;
        }
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimated `q`-quantile (`0 < q <= 1`) from the log-spaced
    /// buckets: the geometric midpoint of the bucket where the
    /// cumulative count crosses `q · count`, clamped into `[min, max]`.
    /// Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let w = (HIST_LOG_MAX - HIST_LOG_MIN) / HIST_BUCKETS as f64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let mid = 10f64.powf(HIST_LOG_MIN + (i as f64 + 0.5) * w);
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median sample estimate.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th-percentile sample estimate.
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th-percentile sample estimate (the straggler tail).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

fn merge_metric(into: &mut BTreeMap<String, MetricVal>, key: &str, v: MetricVal) {
    match (into.get_mut(key), v) {
        (Some(MetricVal::Counter(a)), MetricVal::Counter(b)) => *a += b,
        (Some(MetricVal::Hist(a)), MetricVal::Hist(b)) => a.absorb(b),
        (Some(slot), v) => *slot = v, // gauges and kind changes: last write
        (None, v) => {
            into.insert(key.to_string(), v);
        }
    }
}

/// One rank's (or actor's) recording buffer. Owned exclusively by the
/// recording [`crate::coordinator::RankCtx`] until flushed into the
/// shared [`Tracer`] — no lock is taken per span.
#[derive(Debug, Clone)]
pub struct TrackBuf {
    /// Track id: the rank, or `actor_base + rank` under multi-tenancy.
    pub track: usize,
    /// Completed spans, in emission order (deterministic per rank).
    pub spans: Vec<SpanRec>,
    /// Track-local instant events (e.g. leg warnings).
    pub instants: Vec<InstantRec>,
    /// Track-local metric samples.
    pub metrics: BTreeMap<String, MetricVal>,
    root: Option<usize>,
    open_leg: Option<usize>,
    cur_leg: Option<u32>,
}

impl TrackBuf {
    /// An empty buffer for `track`.
    pub fn new(track: usize) -> Self {
        TrackBuf {
            track,
            spans: Vec::new(),
            instants: Vec::new(),
            metrics: BTreeMap::new(),
            root: None,
            open_leg: None,
            cur_leg: None,
        }
    }

    /// Open the per-rank root span at `start` (normally 0).
    pub fn open_root(&mut self, name: &str, start: f64) {
        self.spans.push(SpanRec {
            name: name.to_string(),
            cat: SpanCat::Collective,
            lane: Lane::Host,
            start,
            dur: f64::NAN,
            charge: None,
            leg: None,
            args: Vec::new(),
        });
        self.root = Some(self.spans.len() - 1);
    }

    /// Open a leg span, closing any previously open one at the same
    /// timestamp (the leg interpreter calls `begin_leg` back to back).
    pub fn open_leg(&mut self, leg: u32, start: f64, args: Vec<(&'static str, String)>) {
        self.close_leg(start);
        self.spans.push(SpanRec {
            name: format!("leg{leg}"),
            cat: SpanCat::Leg,
            lane: Lane::Host,
            start,
            dur: f64::NAN,
            charge: None,
            leg: Some(leg),
            args,
        });
        self.open_leg = Some(self.spans.len() - 1);
        self.cur_leg = Some(leg);
    }

    /// Close the open leg span (no-op when none is open).
    pub fn close_leg(&mut self, end: f64) {
        if let Some(i) = self.open_leg.take() {
            self.spans[i].dur = end - self.spans[i].start;
        }
        self.cur_leg = None;
    }

    /// Record a completed span; the active leg index is attached.
    pub fn span(
        &mut self,
        name: impl Into<String>,
        cat: SpanCat,
        lane: Lane,
        start: f64,
        dur: f64,
        charge: Option<Phase>,
    ) {
        self.span_args(name, cat, lane, start, dur, charge, Vec::new());
    }

    /// Record a completed span with extra key/value annotations (the
    /// message-edge metadata the critical-path analyzer follows). Args
    /// are excluded from [`TraceRun::digest`], so annotating spans
    /// never perturbs the backend-equivalence contract.
    #[allow(clippy::too_many_arguments)]
    pub fn span_args(
        &mut self,
        name: impl Into<String>,
        cat: SpanCat,
        lane: Lane,
        start: f64,
        dur: f64,
        charge: Option<Phase>,
        args: Vec<(&'static str, String)>,
    ) {
        self.spans.push(SpanRec {
            name: name.into(),
            cat,
            lane,
            start,
            dur,
            charge,
            leg: self.cur_leg,
            args,
        });
    }

    /// Record a track-local instant event.
    pub fn instant(&mut self, name: impl Into<String>, t: f64, args: Vec<(&'static str, String)>) {
        self.instants.push(InstantRec {
            name: name.into(),
            t,
            track: Some(self.track),
            args,
        });
    }

    /// Add to a counter metric.
    pub fn counter_add(&mut self, key: &str, v: f64) {
        merge_metric(&mut self.metrics, key, MetricVal::Counter(v));
    }

    /// Record a histogram sample.
    pub fn hist_add(&mut self, key: &str, v: f64) {
        merge_metric(&mut self.metrics, key, MetricVal::Hist(HistStat::one(v)));
    }

    /// Close any open leg and the root span at `end` (flush time).
    pub fn close_all(&mut self, end: f64) {
        self.close_leg(end);
        if let Some(i) = self.root.take() {
            self.spans[i].dur = end - self.spans[i].start;
        }
    }

    /// Phase sums derived from the charged spans — bit-identical to the
    /// [`crate::sim::RankClock`]'s own accounting (same durations added
    /// in the same order).
    pub fn breakdown(&self) -> Breakdown {
        let mut b = Breakdown::default();
        for s in &self.spans {
            if let Some(p) = s.charge {
                b.charge(p, s.dur);
            }
        }
        b
    }

    /// End of the root span (0 when never opened/closed).
    pub fn root_end(&self) -> f64 {
        self.spans
            .iter()
            .find(|s| s.cat == SpanCat::Collective)
            .map_or(0.0, |s| if s.dur.is_nan() { s.start } else { s.end() })
    }
}

/// One completed recording: everything the tracer captured between two
/// [`Tracer::take_run`] drains (normally exactly one collective
/// dispatch).
#[derive(Debug, Clone, Default)]
pub struct TraceRun {
    /// Per-track buffers, keyed by track id (sorted — deterministic).
    pub tracks: BTreeMap<usize, TrackBuf>,
    /// Track id → display label (e.g. `tenantA/3`).
    pub labels: BTreeMap<usize, String>,
    /// Run-global instant events, in record order.
    pub instants: Vec<InstantRec>,
    /// Run-global metrics (e.g. fairness gauges).
    pub metrics: BTreeMap<String, MetricVal>,
    /// Run metadata (op, algo, makespan, …) for the export header.
    pub meta: Vec<(String, String)>,
}

impl TraceRun {
    /// Max root-span end across tracks — equals
    /// `RunReport::makespan` exactly for a traced run.
    pub fn root_end(&self) -> f64 {
        self.tracks.values().map(|t| t.root_end()).fold(0.0, f64::max)
    }

    /// Total spans across all tracks.
    pub fn span_count(&self) -> usize {
        self.tracks.values().map(|t| t.spans.len()).sum()
    }

    /// Total instants (global + per-track).
    pub fn instant_count(&self) -> usize {
        self.instants.len() + self.tracks.values().map(|t| t.instants.len()).sum::<usize>()
    }

    /// Sum of every track's span-derived phase accounting.
    pub fn total_breakdown(&self) -> Breakdown {
        let mut b = Breakdown::default();
        for t in self.tracks.values() {
            b += t.breakdown();
        }
        b
    }

    /// Aggregate every track's metrics plus the run-global ones into a
    /// single registry, with derived per-codec compression ratios.
    pub fn metrics_registry(&self) -> MetricsRegistry {
        let mut reg = MetricsRegistry::default();
        for t in self.tracks.values() {
            for (k, v) in &t.metrics {
                merge_metric(&mut reg.entries, k, *v);
            }
        }
        for (k, v) in &self.metrics {
            merge_metric(&mut reg.entries, k, *v);
        }
        reg.derive_ratios();
        reg
    }

    /// A canonical textual digest of the span tree — track id, lane,
    /// category, leg, name and *bit-exact* timestamps — used by the
    /// backend-equivalence tests. Two digests are equal iff the span
    /// trees are identical in names, nesting and virtual durations.
    pub fn digest(&self) -> String {
        let mut out = String::new();
        for (id, t) in &self.tracks {
            for s in &t.spans {
                use fmt::Write;
                let _ = writeln!(
                    out,
                    "{}|{}|{}|{}|{}|{:016x}|{:016x}",
                    id,
                    s.lane.tid(),
                    s.cat.label(),
                    s.leg.map_or(-1i64, |l| l as i64),
                    s.name,
                    s.start.to_bits(),
                    s.dur.to_bits(),
                );
            }
        }
        out
    }

    /// One-paragraph human summary.
    pub fn summary(&self) -> TraceSummary {
        let reg = self.metrics_registry();
        let mut queue_wait: Option<HistStat> = None;
        for (k, v) in &reg.entries {
            if let (true, MetricVal::Hist(h)) = (k.starts_with("queue_wait_s."), v) {
                match &mut queue_wait {
                    Some(q) => q.absorb(*h),
                    None => queue_wait = Some(*h),
                }
            }
        }
        TraceSummary {
            tracks: self.tracks.len(),
            spans: self.span_count(),
            instants: self.instant_count(),
            root_end: self.root_end(),
            breakdown: self.total_breakdown(),
            queue_wait,
        }
    }

    /// Critical-path extraction, bottleneck attribution and straggler
    /// detection over this run (see [`analysis::analyze`]).
    pub fn analyze(&self) -> analysis::TraceAnalysis {
        analysis::analyze(self)
    }

    /// Structural well-formedness: every span closed with a finite
    /// non-negative duration, and host-lane spans properly nested per
    /// track (the validator CI runs against the exported JSON enforces
    /// the same invariants schema-side).
    pub fn check_well_formed(&self) -> Result<(), String> {
        for (id, t) in &self.tracks {
            let mut host: Vec<&SpanRec> = Vec::new();
            for s in &t.spans {
                if !s.start.is_finite() || !s.dur.is_finite() || s.dur < 0.0 || s.start < 0.0 {
                    return Err(format!(
                        "track {id}: span {:?} has bad interval [{}, +{}]",
                        s.name, s.start, s.dur
                    ));
                }
                if s.lane == Lane::Host {
                    host.push(s);
                }
            }
            // Host spans must nest like a stack: sort by (start asc,
            // end desc) and sweep.
            host.sort_by(|a, b| {
                a.start
                    .partial_cmp(&b.start)
                    .unwrap()
                    .then(b.end().partial_cmp(&a.end()).unwrap())
            });
            let mut stack: Vec<f64> = Vec::new();
            for s in host {
                while let Some(&top) = stack.last() {
                    if top <= s.start {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&top) = stack.last() {
                    if s.end() > top {
                        return Err(format!(
                            "track {id}: host span {:?} [{}, {}] escapes its parent (ends {})",
                            s.name,
                            s.start,
                            s.end(),
                            top
                        ));
                    }
                }
                stack.push(s.end());
            }
        }
        Ok(())
    }

    /// Chrome-trace / Perfetto JSON for this run (virtual time as the
    /// track clock, tracks as processes).
    pub fn to_chrome_json(&self) -> String {
        export::chrome_json(std::slice::from_ref(self))
    }
}

/// Aggregated counters / gauges / histograms, exported as flat JSON.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    /// Metric name → aggregated value, sorted (deterministic export).
    pub entries: BTreeMap<String, MetricVal>,
}

impl MetricsRegistry {
    /// Look up a counter's value (0 when absent).
    pub fn counter(&self, key: &str) -> f64 {
        match self.entries.get(key) {
            Some(MetricVal::Counter(v)) => *v,
            _ => 0.0,
        }
    }

    /// Look up a gauge's value.
    pub fn gauge(&self, key: &str) -> Option<f64> {
        match self.entries.get(key) {
            Some(MetricVal::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    /// Look up a histogram.
    pub fn hist(&self, key: &str) -> Option<HistStat> {
        match self.entries.get(key) {
            Some(MetricVal::Hist(h)) => Some(*h),
            _ => None,
        }
    }

    /// Set a gauge.
    pub fn set_gauge(&mut self, key: &str, v: f64) {
        merge_metric(&mut self.entries, key, MetricVal::Gauge(v));
    }

    /// Derive `cpr_ratio.<codec>` gauges from the per-codec
    /// `cpr_in_bytes.<codec>` / `cpr_out_bytes.<codec>` counter pairs.
    fn derive_ratios(&mut self) {
        let mut ratios = Vec::new();
        for (k, v) in &self.entries {
            if let (Some(codec), MetricVal::Counter(inb)) =
                (k.strip_prefix("cpr_in_bytes."), v)
            {
                let outb = self.counter(&format!("cpr_out_bytes.{codec}"));
                if outb > 0.0 {
                    ratios.push((format!("cpr_ratio.{codec}"), inb / outb));
                }
            }
        }
        for (k, r) in ratios {
            self.set_gauge(&k, r);
        }
    }

    /// Flat metrics JSON (see DESIGN.md for the schema).
    pub fn to_json(&self) -> String {
        export::metrics_json(self)
    }
}

/// Human summary of a [`TraceRun`] (also what
/// `CollectiveReport::trace_summary` prints).
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Number of tracks (ranks/actors) that flushed.
    pub tracks: usize,
    /// Total span count.
    pub spans: usize,
    /// Total instant-event count.
    pub instants: usize,
    /// Max root-span end (== makespan), virtual seconds.
    pub root_end: f64,
    /// Span-derived phase sums over all tracks.
    pub breakdown: Breakdown,
    /// All `queue_wait_s.*` histograms merged (`None` when the run
    /// crossed no shared fabric stage) — the p99 tail is the straggler
    /// signal.
    pub queue_wait: Option<HistStat>,
}

impl fmt::Display for TraceSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "trace: {} tracks, {} spans, {} instants; root end {:.6}s",
            self.tracks, self.spans, self.instants, self.root_end
        )?;
        write!(f, "  span phases: {}", self.breakdown.percent_string())?;
        if let Some(q) = &self.queue_wait {
            write!(
                f,
                "\n  queue-wait: p50 {:.3e}s | p95 {:.3e}s | p99 {:.3e}s | max {:.3e}s",
                q.p50(),
                q.p95(),
                q.p99(),
                q.max
            )?;
        }
        Ok(())
    }
}

#[derive(Debug, Default)]
struct TracerInner {
    tracks: BTreeMap<usize, TrackBuf>,
    labels: BTreeMap<usize, String>,
    instants: Vec<InstantRec>,
    metrics: BTreeMap<String, MetricVal>,
    archive: Vec<Arc<TraceRun>>,
}

/// Cheap cloneable handle to the shared trace sink. Create one, hand it
/// to `CommBuilder::trace` (or set `ClusterSpec::trace`), dispatch
/// collectives, then export with [`Tracer::chrome_json`] /
/// [`Tracer::metrics_json`] — or consume the per-dispatch
/// `CollectiveReport::trace` runs individually.
#[derive(Debug, Clone, Default)]
pub struct Tracer(Arc<Mutex<TracerInner>>);

impl Tracer {
    /// A fresh, empty tracer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Label tracks `base .. base + n` as `label/0 .. label/{n-1}`
    /// (tenant naming under multi-tenant runs).
    pub fn label_tracks(&self, base: usize, n: usize, label: &str) {
        let mut inner = self.0.lock().unwrap();
        for r in 0..n {
            inner.labels.insert(base + r, format!("{label}/{r}"));
        }
    }

    /// Record a run-global instant event.
    pub fn instant(&self, name: &str, t: f64, args: Vec<(&'static str, String)>) {
        self.0.lock().unwrap().instants.push(InstantRec {
            name: name.to_string(),
            t,
            track: None,
            args,
        });
    }

    /// Set a run-global gauge (e.g. `fairness.jain`).
    pub fn gauge(&self, key: &str, v: f64) {
        merge_metric(&mut self.0.lock().unwrap().metrics, key, MetricVal::Gauge(v));
    }

    /// Flush one rank's finished buffer into the sink. Called exactly
    /// once per rank per run, from `RankCtx::finish`.
    pub fn sink(&self, buf: TrackBuf) {
        self.0.lock().unwrap().tracks.insert(buf.track, buf);
    }

    /// Whether anything has been recorded since the last drain.
    pub fn has_pending(&self) -> bool {
        let inner = self.0.lock().unwrap();
        !inner.tracks.is_empty() || !inner.instants.is_empty() || !inner.metrics.is_empty()
    }

    /// Drain everything recorded since the previous drain into a
    /// [`TraceRun`] stamped with `meta`, archiving it for the merged
    /// exporters. One dispatch == one run.
    pub fn take_run(&self, meta: Vec<(String, String)>) -> Arc<TraceRun> {
        let mut inner = self.0.lock().unwrap();
        let run = Arc::new(TraceRun {
            tracks: std::mem::take(&mut inner.tracks),
            labels: inner.labels.clone(),
            instants: std::mem::take(&mut inner.instants),
            metrics: std::mem::take(&mut inner.metrics),
            meta,
        });
        inner.archive.push(run.clone());
        run
    }

    /// Every run drained so far, in dispatch order.
    pub fn runs(&self) -> Vec<Arc<TraceRun>> {
        self.0.lock().unwrap().archive.clone()
    }

    /// Chrome-trace JSON over every archived run (plus any undrained
    /// leftovers), laid out sequentially on one virtual timeline.
    pub fn chrome_json(&self) -> String {
        if self.has_pending() {
            self.take_run(vec![("run".into(), "partial".into())]);
        }
        let runs = self.runs();
        let views: Vec<&TraceRun> = runs.iter().map(|r| r.as_ref()).collect();
        export::chrome_json_refs(&views)
    }

    /// Flat metrics JSON aggregated over every archived run.
    pub fn metrics_json(&self) -> String {
        if self.has_pending() {
            self.take_run(vec![("run".into(), "partial".into())]);
        }
        let mut reg = MetricsRegistry::default();
        for run in self.runs() {
            for (k, v) in run.metrics_registry().entries {
                merge_metric(&mut reg.entries, &k, v);
            }
        }
        reg.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf_with_spans() -> TrackBuf {
        let mut b = TrackBuf::new(0);
        b.open_root("collective", 0.0);
        b.open_leg(0, 0.0, vec![]);
        b.span("issue", SpanCat::Phase, Lane::Host, 0.0, 1.0, Some(Phase::Other));
        b.span("compress", SpanCat::Phase, Lane::Gpu(0), 1.0, 2.0, Some(Phase::Cpr));
        b.open_leg(1, 3.0, vec![]);
        b.span("recv-wait", SpanCat::Phase, Lane::Host, 3.0, 0.5, Some(Phase::Comm));
        b.close_all(4.0);
        b
    }

    #[test]
    fn spans_nest_and_breakdown_sums() {
        let b = buf_with_spans();
        assert_eq!(b.root_end(), 4.0);
        let bd = b.breakdown();
        assert_eq!(bd.get(Phase::Other), 1.0);
        assert_eq!(bd.get(Phase::Cpr), 2.0);
        assert_eq!(bd.get(Phase::Comm), 0.5);
        // Leg 0 closed exactly where leg 1 opened.
        let leg0 = b.spans.iter().find(|s| s.name == "leg0").unwrap();
        assert_eq!(leg0.end(), 3.0);
        let leg1 = b.spans.iter().find(|s| s.name == "leg1").unwrap();
        assert_eq!((leg1.start, leg1.end()), (3.0, 4.0));
        // The recv-wait rode leg 1's index.
        let rw = b.spans.iter().find(|s| s.name == "recv-wait").unwrap();
        assert_eq!(rw.leg, Some(1));
    }

    #[test]
    fn tracer_drains_into_runs() {
        let tr = Tracer::new();
        tr.sink(buf_with_spans());
        tr.instant("tuner-decision", 0.0, vec![("algo", "Ring".into())]);
        tr.gauge("fairness.jain", 0.97);
        assert!(tr.has_pending());
        let run = tr.take_run(vec![("op".into(), "Allreduce".into())]);
        assert!(!tr.has_pending());
        assert_eq!(run.tracks.len(), 1);
        assert_eq!(run.instant_count(), 1);
        assert_eq!(run.root_end(), 4.0);
        assert!(run.check_well_formed().is_ok());
        let reg = run.metrics_registry();
        assert_eq!(reg.gauge("fairness.jain"), Some(0.97));
        // Drained again: empty.
        let run2 = tr.take_run(vec![]);
        assert_eq!(run2.span_count(), 0);
        assert_eq!(tr.runs().len(), 2);
    }

    #[test]
    fn digests_are_bit_exact() {
        let tr = Tracer::new();
        tr.sink(buf_with_spans());
        let a = tr.take_run(vec![]).digest();
        let tr2 = Tracer::new();
        tr2.sink(buf_with_spans());
        let b = tr2.take_run(vec![]).digest();
        assert_eq!(a, b);
        assert!(a.contains("compress"));
    }

    #[test]
    fn metrics_merge_across_tracks() {
        let mut a = TrackBuf::new(0);
        a.counter_add("wire_bytes.internode", 100.0);
        a.hist_add("queue_wait_s.nic", 1.0);
        let mut b = TrackBuf::new(1);
        b.counter_add("wire_bytes.internode", 50.0);
        b.hist_add("queue_wait_s.nic", 3.0);
        b.counter_add("cpr_in_bytes.cuszp", 80.0);
        b.counter_add("cpr_out_bytes.cuszp", 20.0);
        let tr = Tracer::new();
        tr.sink(a);
        tr.sink(b);
        let reg = tr.take_run(vec![]).metrics_registry();
        assert_eq!(reg.counter("wire_bytes.internode"), 150.0);
        let h = reg.hist("queue_wait_s.nic").unwrap();
        assert_eq!((h.count, h.sum, h.min, h.max), (2, 4.0, 1.0, 3.0));
        assert_eq!(h.mean(), 2.0);
        assert_eq!(reg.gauge("cpr_ratio.cuszp"), Some(4.0));
    }

    #[test]
    fn hist_quantiles_track_the_tail() {
        let mut b = TrackBuf::new(0);
        for i in 1..=100 {
            b.hist_add("queue_wait_s.nic", i as f64 * 1e-6);
        }
        let tr = Tracer::new();
        tr.sink(b);
        let run = tr.take_run(vec![]);
        let h = run.metrics_registry().hist("queue_wait_s.nic").unwrap();
        assert_eq!(h.count, 100);
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max, "{p50} {p95} {p99}");
        // Log-bucket estimates land within one bucket (~1.54x) of the
        // exact order statistics.
        assert!((25e-6..=80e-6).contains(&p50), "p50 {p50}");
        assert!((60e-6..=100e-6).contains(&p99), "p99 {p99}");
        // The summary surfaces the merged queue-wait histogram.
        let s = run.summary();
        assert_eq!(s.queue_wait.unwrap().count, 100);
        assert!(format!("{s}").contains("queue-wait: p50"));
    }

    #[test]
    fn well_formed_catches_escapes() {
        let mut b = TrackBuf::new(0);
        b.open_root("collective", 0.0);
        b.open_leg(0, 0.0, vec![]);
        b.close_leg(1.0);
        // A host span escaping its (closed) parent leg is still fine as
        // long as it fits the root; one escaping the root is not.
        b.span("ok", SpanCat::Phase, Lane::Host, 0.5, 0.25, None);
        b.close_all(2.0);
        let tr = Tracer::new();
        tr.sink(b.clone());
        assert!(tr.take_run(vec![]).check_well_formed().is_ok());
        b.span("bad", SpanCat::Phase, Lane::Host, 1.5, 10.0, None);
        let tr2 = Tracer::new();
        tr2.sink(b);
        assert!(tr2.take_run(vec![]).check_well_formed().is_err());
    }
}
