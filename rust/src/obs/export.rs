//! JSON exporters: Chrome-trace/Perfetto events and flat metrics.
//!
//! The trace format is the Chrome trace-event JSON object form
//! (`{"traceEvents": [...]}`), loadable in <https://ui.perfetto.dev>
//! and `chrome://tracing`. Virtual time is the track clock (`ts`/`dur`
//! in virtual microseconds); each track (rank, or tenant actor) is a
//! process, with lanes (host / net / copy engines / GPU streams) as
//! threads. Spans are complete events (`"ph": "X"`), instant events are
//! `"ph": "i"`, and track naming uses the standard `"M"` metadata
//! events — no `B`/`E` pairs are ever emitted, so balance is
//! structural. Multiple runs are laid out sequentially on one timeline,
//! separated by run-boundary instants.
//!
//! Everything is hand-formatted (the crate is std-only, like the bench
//! artifact writers); string values pass through [`esc`]. The inverse
//! direction — [`import_chrome_json`] — parses a previously exported
//! file back into its [`TraceRun`]s so `gzccl analyze` can run the
//! critical-path analyzer offline, long after the simulating process
//! exited.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write;

use super::analysis::TraceAnalysis;
use super::{InstantRec, Lane, MetricVal, MetricsRegistry, SpanCat, SpanRec, TraceRun, TrackBuf};
use crate::sim::Phase;

/// Escape a string for embedding in a JSON string literal.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Format virtual seconds as trace microseconds (ns resolution).
fn us(t: f64) -> String {
    format!("{:.3}", t * 1e6)
}

fn args_json(args: &[(&'static str, String)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\": \"{}\"", esc(k), esc(v));
    }
    out.push('}');
    out
}

fn instant_event(ev: &InstantRec, offset: f64, scope: &str, pid: usize) -> String {
    format!(
        "{{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"{}\", \"pid\": {}, \"tid\": 0, \
         \"ts\": {}, \"args\": {}}}",
        esc(&ev.name),
        scope,
        pid,
        us(ev.t + offset),
        args_json(&ev.args),
    )
}

/// Chrome-trace JSON over owned runs (see module docs).
pub fn chrome_json(runs: &[TraceRun]) -> String {
    let refs: Vec<&TraceRun> = runs.iter().collect();
    chrome_json_refs(&refs)
}

/// Chrome-trace JSON over borrowed runs, laid out sequentially.
pub fn chrome_json_refs(runs: &[&TraceRun]) -> String {
    chrome_json_with_extra(runs, &[])
}

/// Chrome-trace JSON with extra pre-rendered events appended — the
/// CLI's critical-path overlay track (see [`critical_path_events`]).
pub fn chrome_json_with_extra(runs: &[&TraceRun], extra: &[String]) -> String {
    let mut events: Vec<String> = Vec::new();
    // Track naming metadata: union over runs, first label wins.
    let mut named: BTreeSet<usize> = BTreeSet::new();
    for run in runs {
        for (&id, track) in &run.tracks {
            if !named.insert(id) {
                continue;
            }
            let label = run
                .labels
                .get(&id)
                .cloned()
                .unwrap_or_else(|| format!("rank {id}"));
            events.push(format!(
                "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {id}, \
                 \"args\": {{\"name\": \"{}\"}}}}",
                esc(&label)
            ));
            events.push(format!(
                "{{\"name\": \"process_sort_index\", \"ph\": \"M\", \"pid\": {id}, \
                 \"args\": {{\"sort_index\": {id}}}}}"
            ));
            let mut seen = BTreeSet::new();
            for s in &track.spans {
                if seen.insert(s.lane.tid()) {
                    events.push(format!(
                        "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {id}, \
                         \"tid\": {}, \"args\": {{\"name\": \"{}\"}}}}",
                        s.lane.tid(),
                        esc(&s.lane.label())
                    ));
                }
            }
        }
    }
    // Span + instant payload, one run after another on the timeline.
    let mut offset = 0.0f64;
    for (ri, run) in runs.iter().enumerate() {
        if runs.len() > 1 {
            events.push(format!(
                "{{\"name\": \"run {ri} start\", \"ph\": \"i\", \"s\": \"g\", \"pid\": 0, \
                 \"tid\": 0, \"ts\": {}, \"args\": {}}}",
                us(offset),
                meta_json(&run.meta),
            ));
        }
        for (&id, track) in &run.tracks {
            for s in &track.spans {
                let mut args = vec![];
                if let Some(p) = s.charge {
                    args.push(("phase", p.label().to_string()));
                }
                if let Some(l) = s.leg {
                    args.push(("leg", l.to_string()));
                }
                args.extend(s.args.iter().cloned());
                events.push(format!(
                    "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"pid\": {id}, \
                     \"tid\": {}, \"ts\": {}, \"dur\": {}, \"args\": {}}}",
                    esc(&s.name),
                    s.cat.label(),
                    s.lane.tid(),
                    us(s.start + offset),
                    us(s.dur),
                    args_json(&args),
                ));
            }
            for ev in &track.instants {
                events.push(instant_event(ev, offset, "t", id));
            }
        }
        for ev in &run.instants {
            events.push(instant_event(ev, offset, "g", 0));
        }
        offset += run.root_end();
    }
    events.extend(extra.iter().cloned());
    let meta = if runs.len() == 1 { meta_json(&runs[0].meta) } else { "{}".to_string() };
    format!(
        "{{\n\"displayTimeUnit\": \"ms\",\n\"otherData\": {meta},\n\"traceEvents\": [\n{}\n]\n}}\n",
        events.join(",\n")
    )
}

fn meta_json(meta: &[(String, String)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\": \"{}\"", esc(k), esc(v));
    }
    out.push('}');
    out
}

/// Synthetic process id for the critical-path overlay track — far above
/// any real rank/actor id, and skipped by [`import_chrome_json`] (the
/// overlay is derived data, recomputable from the spans).
pub const CRITICAL_PATH_PID: usize = 1_000_000;

/// Render an extracted critical path as a dedicated Perfetto track
/// (process [`CRITICAL_PATH_PID`], sorted above the rank tracks): one
/// complete event per path segment, in time order, annotated with its
/// category and source track. `offset` shifts the segments onto a
/// multi-run timeline (the run's start offset, 0 for a single run).
/// Feed the result to [`chrome_json_with_extra`].
pub fn critical_path_events(a: &TraceAnalysis, offset: f64) -> Vec<String> {
    let mut events = Vec::new();
    if a.critical_path.segments.is_empty() {
        return events;
    }
    events.push(format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {CRITICAL_PATH_PID}, \
         \"args\": {{\"name\": \"critical path\"}}}}"
    ));
    events.push(format!(
        "{{\"name\": \"process_sort_index\", \"ph\": \"M\", \"pid\": {CRITICAL_PATH_PID}, \
         \"args\": {{\"sort_index\": -1}}}}"
    ));
    events.push(format!(
        "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {CRITICAL_PATH_PID}, \
         \"tid\": 0, \"args\": {{\"name\": \"chain\"}}}}"
    ));
    for s in &a.critical_path.segments {
        let mut args = vec![
            ("category", s.category.label().to_string()),
            ("track", s.track.to_string()),
        ];
        if let Some(l) = s.leg {
            args.push(("leg", l.to_string()));
        }
        if let Some(t) = s.tier {
            args.push(("tier", t.to_string()));
        }
        if s.queue_s > 0.0 {
            args.push(("queue_s", format!("{:e}", s.queue_s)));
        }
        events.push(format!(
            "{{\"name\": \"{}\", \"cat\": \"critical\", \"ph\": \"X\", \
             \"pid\": {CRITICAL_PATH_PID}, \"tid\": 0, \"ts\": {}, \"dur\": {}, \"args\": {}}}",
            esc(&s.label),
            us(s.start + offset),
            us(s.dur()),
            args_json(&args),
        ));
    }
    events
}

/// Flat metrics JSON: one sorted object of typed entries.
pub fn metrics_json(reg: &MetricsRegistry) -> String {
    let mut body: Vec<String> = Vec::new();
    for (k, v) in &reg.entries {
        let entry = match v {
            MetricVal::Counter(c) => {
                format!("    \"{}\": {{\"type\": \"counter\", \"value\": {c}}}", esc(k))
            }
            MetricVal::Gauge(g) => {
                format!("    \"{}\": {{\"type\": \"gauge\", \"value\": {g}}}", esc(k))
            }
            MetricVal::Hist(h) => format!(
                "    \"{}\": {{\"type\": \"histogram\", \"count\": {}, \"sum\": {}, \
                 \"min\": {}, \"max\": {}, \"mean\": {}, \"p50\": {}, \"p95\": {}, \
                 \"p99\": {}}}",
                esc(k),
                h.count,
                h.sum,
                h.min,
                h.max,
                h.mean(),
                h.p50(),
                h.p95(),
                h.p99()
            ),
        };
        body.push(entry);
    }
    format!(
        "{{\n  \"schema_version\": 1,\n  \"metrics\": {{\n{}\n  }}\n}}\n",
        body.join(",\n")
    )
}

/// Minimal JSON value for the importer (std-only crate — no serde).
#[derive(Debug)]
enum Jv {
    Null,
    // The payload is never inspected (the trace format carries no
    // booleans) but a robust parser still has to represent it.
    #[allow(dead_code)]
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Jv>),
    Obj(Vec<(String, Jv)>),
}

impl Jv {
    fn get(&self, key: &str) -> Option<&Jv> {
        match self {
            Jv::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Jv::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_num(&self) -> Option<f64> {
        match self {
            Jv::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Recursive-descent JSON parser over raw bytes. Unescaped string runs
/// are copied slice-at-a-time (splitting on `"` / `\` is multi-byte
/// safe: both are ASCII and UTF-8 continuation bytes are `>= 0x80`).
struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("json: expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Jv, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Jv::Str(self.string()?)),
            Some(b't') => self.lit("true", Jv::Bool(true)),
            Some(b'f') => self.lit("false", Jv::Bool(false)),
            Some(b'n') => self.lit("null", Jv::Null),
            Some(_) => self.number(),
            None => Err("json: unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Jv) -> Result<Jv, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("json: bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Jv, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Jv::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Jv::Obj(kv));
                }
                _ => return Err(format!("json: expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Jv, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Jv::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Jv::Arr(items));
                }
                _ => return Err(format!("json: expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("json: unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or("json: truncated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("json: truncated \\u escape")?;
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| format!("json: bad \\u escape at {}", self.i))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("json: bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| format!("json: bad utf-8 in string: {e}"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Jv, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Jv::Num)
            .ok_or_else(|| format!("json: bad number at byte {start}"))
    }
}

fn parse_json(s: &str) -> Result<Jv, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("json: trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

/// Annotation keys the importer preserves. Args carry `&'static str`
/// keys in memory, so re-imported annotations must intern onto the
/// exporter's vocabulary; anything it never writes is dropped.
const KNOWN_KEYS: &[&str] = &[
    "algo",
    "arrival",
    "bytes",
    "category",
    "codec",
    "dst",
    "eb",
    "leg",
    "message",
    "mode",
    "observed_max_err",
    "op",
    "per_call_abs",
    "phase",
    "pred_legs",
    "pred_makespan",
    "queue_s",
    "rejected",
    "scale_after",
    "scale_before",
    "source",
    "src",
    "streams",
    "stuck",
    "tier",
    "track",
    "vetoed",
    "waits",
];

fn intern_key(k: &str) -> Option<&'static str> {
    KNOWN_KEYS.iter().copied().find(|x| *x == k)
}

/// Collect an event's string args, interning keys. `span` drops the
/// exporter-injected `phase` / `leg` pair (folded back into the
/// [`SpanRec`] fields instead); instants keep them verbatim.
fn import_args(v: Option<&Jv>, span: bool) -> Vec<(&'static str, String)> {
    let Some(Jv::Obj(kv)) = v else {
        return Vec::new();
    };
    kv.iter()
        .filter(|(k, _)| !(span && (k == "phase" || k == "leg")))
        .filter_map(|(k, val)| Some((intern_key(k)?, val.as_str()?.to_string())))
        .collect()
}

fn str_pairs(v: Option<&Jv>) -> Vec<(String, String)> {
    let Some(Jv::Obj(kv)) = v else {
        return Vec::new();
    };
    kv.iter()
        .filter_map(|(k, val)| Some((k.clone(), val.as_str()?.to_string())))
        .collect()
}

fn phase_from_label(l: &str) -> Option<Phase> {
    Phase::ALL.into_iter().find(|p| p.label() == l)
}

fn cat_from_label(l: &str) -> SpanCat {
    match l {
        "collective" => SpanCat::Collective,
        "leg" => SpanCat::Leg,
        "codec" => SpanCat::Codec,
        "net" => SpanCat::Net,
        _ => SpanCat::Phase,
    }
}

fn lane_from_tid(tid: u32) -> Lane {
    match tid {
        0 => Lane::Host,
        1 => Lane::Net,
        2 => Lane::H2d,
        3 => Lane::D2h,
        n => Lane::Gpu(n - 4),
    }
}

/// A multi-run layout's `"run N start"` boundary marker.
fn is_run_marker(name: &str) -> bool {
    name.strip_prefix("run ")
        .and_then(|r| r.strip_suffix(" start"))
        .is_some_and(|n| n.parse::<usize>().is_ok())
}

/// Get (or lazily start) the run currently receiving payload events.
fn current_run<'r>(
    runs: &'r mut Vec<(f64, TraceRun)>,
    other: &[(String, String)],
) -> &'r mut (f64, TraceRun) {
    if runs.is_empty() {
        runs.push((
            0.0,
            TraceRun {
                meta: other.to_vec(),
                ..TraceRun::default()
            },
        ));
    }
    runs.last_mut().expect("just ensured non-empty")
}

/// Parse a Chrome-trace JSON file written by [`chrome_json`] back into
/// its [`TraceRun`]s — the `gzccl analyze FILE` entry point.
///
/// Inverse of the exporter up to its serialization losses: timestamps
/// come back at the export's ns resolution (wire-edge identity survives
/// anyway — the analyzer keys message hops on the verbatim `arrival`
/// annotation, not on rounded span ends), metrics live in the separate
/// sidecar file and come back empty, and annotation keys outside the
/// exporter's vocabulary are dropped. Multi-run files split on the
/// `"run N start"` boundary markers with their offsets removed; the
/// critical-path overlay track, being derived data, is skipped.
pub fn import_chrome_json(s: &str) -> Result<Vec<TraceRun>, String> {
    let top = parse_json(s)?;
    let Some(Jv::Arr(events)) = top.get("traceEvents") else {
        return Err("trace: missing traceEvents array".into());
    };
    let other = str_pairs(top.get("otherData"));
    let mut labels: BTreeMap<usize, String> = BTreeMap::new();
    // (timeline offset, run) pairs: runs begin at boundary markers, or
    // at the first payload event for single-run files.
    let mut runs: Vec<(f64, TraceRun)> = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Jv::as_str).unwrap_or("");
        let name = ev.get("name").and_then(Jv::as_str).unwrap_or("");
        let pid = ev.get("pid").and_then(Jv::as_num).unwrap_or(0.0) as usize;
        match ph {
            "M" => {
                if name == "process_name" && pid != CRITICAL_PATH_PID {
                    if let Some(l) =
                        ev.get("args").and_then(|a| a.get("name")).and_then(Jv::as_str)
                    {
                        labels.insert(pid, l.to_string());
                    }
                }
            }
            "i" => {
                let ts = ev.get("ts").and_then(Jv::as_num).unwrap_or(0.0) / 1e6;
                if pid == 0 && is_run_marker(name) {
                    runs.push((
                        ts,
                        TraceRun {
                            meta: str_pairs(ev.get("args")),
                            ..TraceRun::default()
                        },
                    ));
                    continue;
                }
                let cur = current_run(&mut runs, &other);
                let t = ts - cur.0;
                let args = import_args(ev.get("args"), false);
                if ev.get("s").and_then(Jv::as_str) == Some("t") {
                    let buf = cur.1.tracks.entry(pid).or_insert_with(|| TrackBuf::new(pid));
                    buf.instants.push(InstantRec {
                        name: name.to_string(),
                        t,
                        track: Some(pid),
                        args,
                    });
                } else {
                    cur.1.instants.push(InstantRec {
                        name: name.to_string(),
                        t,
                        track: None,
                        args,
                    });
                }
            }
            "X" => {
                if pid == CRITICAL_PATH_PID {
                    continue;
                }
                let ts = ev.get("ts").and_then(Jv::as_num).unwrap_or(0.0) / 1e6;
                let dur = ev.get("dur").and_then(Jv::as_num).unwrap_or(0.0) / 1e6;
                let tid = ev.get("tid").and_then(Jv::as_num).unwrap_or(0.0) as u32;
                let args_v = ev.get("args");
                let charge = args_v
                    .and_then(|a| a.get("phase"))
                    .and_then(Jv::as_str)
                    .and_then(phase_from_label);
                let leg = args_v
                    .and_then(|a| a.get("leg"))
                    .and_then(Jv::as_str)
                    .and_then(|l| l.parse::<u32>().ok());
                let cur = current_run(&mut runs, &other);
                let start = ts - cur.0;
                let buf = cur.1.tracks.entry(pid).or_insert_with(|| TrackBuf::new(pid));
                buf.spans.push(SpanRec {
                    name: name.to_string(),
                    cat: cat_from_label(ev.get("cat").and_then(Jv::as_str).unwrap_or("phase")),
                    lane: lane_from_tid(tid),
                    start,
                    dur,
                    charge,
                    leg,
                    args: import_args(args_v, true),
                });
            }
            _ => {}
        }
    }
    if runs.is_empty() {
        return Err("trace: no runs found".into());
    }
    let mut out: Vec<TraceRun> = runs.into_iter().map(|(_, r)| r).collect();
    for run in &mut out {
        run.labels = labels
            .iter()
            .filter(|(id, _)| run.tracks.contains_key(id))
            .map(|(id, l)| (*id, l.clone()))
            .collect();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::{Lane, SpanCat, TrackBuf, Tracer};
    use super::*;
    use crate::sim::Phase;

    fn run() -> TraceRun {
        let tr = Tracer::new();
        let mut b = TrackBuf::new(3);
        b.open_root("collective", 0.0);
        b.span("compress", SpanCat::Phase, Lane::Gpu(0), 0.5e-6, 1.0e-6, Some(Phase::Cpr));
        b.instant("leg-warning", 1e-6, vec![("message", "q\"uote".into())]);
        b.counter_add("wire_bytes.internode", 64.0);
        b.close_all(2e-6);
        tr.sink(b);
        tr.instant("tuner-decision", 0.0, vec![("algo", "Ring".into())]);
        std::sync::Arc::try_unwrap(tr.take_run(vec![("op".into(), "Allreduce".into())]))
            .ok()
            .unwrap()
    }

    #[test]
    fn chrome_json_shape() {
        let j = run().to_chrome_json();
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"traceEvents\": ["));
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"ph\": \"i\""));
        assert!(j.contains("\"ph\": \"M\""));
        assert!(j.contains("\"name\": \"rank 3\""));
        assert!(j.contains("\"phase\": \"CPR\""));
        // Escaped quote in the warning message survived.
        assert!(j.contains("q\\\"uote"));
        // No unbalanced begin/end events are ever emitted.
        assert!(!j.contains("\"ph\": \"B\"") && !j.contains("\"ph\": \"E\""));
    }

    #[test]
    fn multi_run_layout_offsets_sequentially() {
        let a = run();
        let b = run();
        let j = chrome_json(&[a.clone(), b]);
        assert!(j.contains("run 0 start"));
        assert!(j.contains("run 1 start"));
        // Second run's root starts at the first run's end (2 us).
        assert!(j.contains("\"ts\": 2.000"));
        let _ = a;
    }

    #[test]
    fn metrics_json_shape() {
        let reg = run().metrics_registry();
        let j = reg.to_json();
        assert!(j.contains("\"wire_bytes.internode\": {\"type\": \"counter\", \"value\": 64}"));
        assert!(j.contains("\"schema_version\": 1"));
    }

    #[test]
    fn metrics_hist_line_carries_quantiles() {
        let tr = Tracer::new();
        let mut b = TrackBuf::new(0);
        b.hist_add("queue_wait_s.nic", 2e-6);
        b.hist_add("queue_wait_s.nic", 8e-6);
        tr.sink(b);
        let j = tr.take_run(vec![]).metrics_registry().to_json();
        assert!(j.contains("\"type\": \"histogram\""));
        assert!(j.contains("\"p50\":") && j.contains("\"p95\":") && j.contains("\"p99\":"), "{j}");
    }

    #[test]
    fn chrome_json_round_trips_through_the_importer() {
        let a = run();
        let back = import_chrome_json(&a.to_chrome_json()).unwrap();
        assert_eq!(back.len(), 1);
        let r = &back[0];
        assert_eq!(r.tracks.len(), 1);
        let t = &r.tracks[&3];
        assert_eq!(t.spans.len(), a.tracks[&3].spans.len());
        let cpr = t.spans.iter().find(|s| s.name == "compress").unwrap();
        assert_eq!(cpr.charge, Some(Phase::Cpr));
        assert_eq!(cpr.lane, Lane::Gpu(0));
        assert_eq!(cpr.cat, SpanCat::Phase);
        assert!((cpr.start - 0.5e-6).abs() < 1e-12 && (cpr.dur - 1.0e-6).abs() < 1e-12);
        // One track-local warning (escaped quote intact), one global
        // decision, meta and the synthesized rank label.
        assert_eq!(t.instants.len(), 1);
        assert_eq!(t.instants[0].args, vec![("message", "q\"uote".to_string())]);
        assert_eq!(r.instants.len(), 1);
        assert_eq!(r.instants[0].name, "tuner-decision");
        assert_eq!(r.meta, vec![("op".to_string(), "Allreduce".to_string())]);
        assert_eq!(r.labels.get(&3).map(String::as_str), Some("rank 3"));
        // The analyzer runs on the re-imported run.
        assert!(r.analyze().critical_path.total_s() > 0.0);
    }

    #[test]
    fn multi_run_import_splits_on_markers() {
        let j = chrome_json(&[run(), run()]);
        let back = import_chrome_json(&j).unwrap();
        assert_eq!(back.len(), 2);
        for r in &back {
            assert_eq!(r.meta, vec![("op".to_string(), "Allreduce".to_string())]);
            // Offsets removed: both runs sit back at [0, 2 us].
            assert!((r.root_end() - 2e-6).abs() < 1e-12, "{}", r.root_end());
        }
    }

    #[test]
    fn critical_path_overlay_rides_the_export_and_skips_the_import() {
        let a = run();
        let extra = critical_path_events(&a.analyze(), 0.0);
        assert!(!extra.is_empty());
        let j = chrome_json_with_extra(&[&a], &extra);
        assert!(j.contains("\"critical path\""));
        assert!(j.contains("\"cat\": \"critical\""));
        let back = import_chrome_json(&j).unwrap();
        assert_eq!(back[0].span_count(), a.span_count());
        assert!(!back[0].tracks.contains_key(&CRITICAL_PATH_PID));
    }

    #[test]
    fn importer_rejects_garbage() {
        assert!(import_chrome_json("not json").is_err());
        assert!(import_chrome_json("{}").is_err());
        assert!(import_chrome_json("{\"traceEvents\": []}").is_err());
    }

    #[test]
    fn escaping_covers_controls() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
