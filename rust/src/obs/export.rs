//! JSON exporters: Chrome-trace/Perfetto events and flat metrics.
//!
//! The trace format is the Chrome trace-event JSON object form
//! (`{"traceEvents": [...]}`), loadable in <https://ui.perfetto.dev>
//! and `chrome://tracing`. Virtual time is the track clock (`ts`/`dur`
//! in virtual microseconds); each track (rank, or tenant actor) is a
//! process, with lanes (host / net / copy engines / GPU streams) as
//! threads. Spans are complete events (`"ph": "X"`), instant events are
//! `"ph": "i"`, and track naming uses the standard `"M"` metadata
//! events — no `B`/`E` pairs are ever emitted, so balance is
//! structural. Multiple runs are laid out sequentially on one timeline,
//! separated by run-boundary instants.
//!
//! Everything is hand-formatted (the crate is std-only, like the bench
//! artifact writers); string values pass through [`esc`].

use std::collections::BTreeSet;
use std::fmt::Write;

use super::{InstantRec, MetricVal, MetricsRegistry, TraceRun};

/// Escape a string for embedding in a JSON string literal.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Format virtual seconds as trace microseconds (ns resolution).
fn us(t: f64) -> String {
    format!("{:.3}", t * 1e6)
}

fn args_json(args: &[(&'static str, String)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\": \"{}\"", esc(k), esc(v));
    }
    out.push('}');
    out
}

fn instant_event(ev: &InstantRec, offset: f64, scope: &str, pid: usize) -> String {
    format!(
        "{{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"{}\", \"pid\": {}, \"tid\": 0, \
         \"ts\": {}, \"args\": {}}}",
        esc(&ev.name),
        scope,
        pid,
        us(ev.t + offset),
        args_json(&ev.args),
    )
}

/// Chrome-trace JSON over owned runs (see module docs).
pub fn chrome_json(runs: &[TraceRun]) -> String {
    let refs: Vec<&TraceRun> = runs.iter().collect();
    chrome_json_refs(&refs)
}

/// Chrome-trace JSON over borrowed runs, laid out sequentially.
pub fn chrome_json_refs(runs: &[&TraceRun]) -> String {
    let mut events: Vec<String> = Vec::new();
    // Track naming metadata: union over runs, first label wins.
    let mut named: BTreeSet<usize> = BTreeSet::new();
    for run in runs {
        for (&id, track) in &run.tracks {
            if !named.insert(id) {
                continue;
            }
            let label = run
                .labels
                .get(&id)
                .cloned()
                .unwrap_or_else(|| format!("rank {id}"));
            events.push(format!(
                "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {id}, \
                 \"args\": {{\"name\": \"{}\"}}}}",
                esc(&label)
            ));
            events.push(format!(
                "{{\"name\": \"process_sort_index\", \"ph\": \"M\", \"pid\": {id}, \
                 \"args\": {{\"sort_index\": {id}}}}}"
            ));
            let mut seen = BTreeSet::new();
            for s in &track.spans {
                if seen.insert(s.lane.tid()) {
                    events.push(format!(
                        "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {id}, \
                         \"tid\": {}, \"args\": {{\"name\": \"{}\"}}}}",
                        s.lane.tid(),
                        esc(&s.lane.label())
                    ));
                }
            }
        }
    }
    // Span + instant payload, one run after another on the timeline.
    let mut offset = 0.0f64;
    for (ri, run) in runs.iter().enumerate() {
        if runs.len() > 1 {
            events.push(format!(
                "{{\"name\": \"run {ri} start\", \"ph\": \"i\", \"s\": \"g\", \"pid\": 0, \
                 \"tid\": 0, \"ts\": {}, \"args\": {}}}",
                us(offset),
                meta_json(&run.meta),
            ));
        }
        for (&id, track) in &run.tracks {
            for s in &track.spans {
                let mut args = vec![];
                if let Some(p) = s.charge {
                    args.push(("phase", p.label().to_string()));
                }
                if let Some(l) = s.leg {
                    args.push(("leg", l.to_string()));
                }
                args.extend(s.args.iter().cloned());
                events.push(format!(
                    "{{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"pid\": {id}, \
                     \"tid\": {}, \"ts\": {}, \"dur\": {}, \"args\": {}}}",
                    esc(&s.name),
                    s.cat.label(),
                    s.lane.tid(),
                    us(s.start + offset),
                    us(s.dur),
                    args_json(&args),
                ));
            }
            for ev in &track.instants {
                events.push(instant_event(ev, offset, "t", id));
            }
        }
        for ev in &run.instants {
            events.push(instant_event(ev, offset, "g", 0));
        }
        offset += run.root_end();
    }
    let meta = if runs.len() == 1 { meta_json(&runs[0].meta) } else { "{}".to_string() };
    format!(
        "{{\n\"displayTimeUnit\": \"ms\",\n\"otherData\": {meta},\n\"traceEvents\": [\n{}\n]\n}}\n",
        events.join(",\n")
    )
}

fn meta_json(meta: &[(String, String)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{}\": \"{}\"", esc(k), esc(v));
    }
    out.push('}');
    out
}

/// Flat metrics JSON: one sorted object of typed entries.
pub fn metrics_json(reg: &MetricsRegistry) -> String {
    let mut body: Vec<String> = Vec::new();
    for (k, v) in &reg.entries {
        let entry = match v {
            MetricVal::Counter(c) => {
                format!("    \"{}\": {{\"type\": \"counter\", \"value\": {c}}}", esc(k))
            }
            MetricVal::Gauge(g) => {
                format!("    \"{}\": {{\"type\": \"gauge\", \"value\": {g}}}", esc(k))
            }
            MetricVal::Hist(h) => format!(
                "    \"{}\": {{\"type\": \"histogram\", \"count\": {}, \"sum\": {}, \
                 \"min\": {}, \"max\": {}, \"mean\": {}}}",
                esc(k),
                h.count,
                h.sum,
                h.min,
                h.max,
                h.mean()
            ),
        };
        body.push(entry);
    }
    format!(
        "{{\n  \"schema_version\": 1,\n  \"metrics\": {{\n{}\n  }}\n}}\n",
        body.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::super::{Lane, SpanCat, TrackBuf, Tracer};
    use super::*;
    use crate::sim::Phase;

    fn run() -> TraceRun {
        let tr = Tracer::new();
        let mut b = TrackBuf::new(3);
        b.open_root("collective", 0.0);
        b.span("compress", SpanCat::Phase, Lane::Gpu(0), 0.5e-6, 1.0e-6, Some(Phase::Cpr));
        b.instant("leg-warning", 1e-6, vec![("message", "q\"uote".into())]);
        b.counter_add("wire_bytes.internode", 64.0);
        b.close_all(2e-6);
        tr.sink(b);
        tr.instant("tuner-decision", 0.0, vec![("algo", "Ring".into())]);
        std::sync::Arc::try_unwrap(tr.take_run(vec![("op".into(), "Allreduce".into())]))
            .ok()
            .unwrap()
    }

    #[test]
    fn chrome_json_shape() {
        let j = run().to_chrome_json();
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"traceEvents\": ["));
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"ph\": \"i\""));
        assert!(j.contains("\"ph\": \"M\""));
        assert!(j.contains("\"name\": \"rank 3\""));
        assert!(j.contains("\"phase\": \"CPR\""));
        // Escaped quote in the warning message survived.
        assert!(j.contains("q\\\"uote"));
        // No unbalanced begin/end events are ever emitted.
        assert!(!j.contains("\"ph\": \"B\"") && !j.contains("\"ph\": \"E\""));
    }

    #[test]
    fn multi_run_layout_offsets_sequentially() {
        let a = run();
        let b = run();
        let j = chrome_json(&[a.clone(), b]);
        assert!(j.contains("run 0 start"));
        assert!(j.contains("run 1 start"));
        // Second run's root starts at the first run's end (2 us).
        assert!(j.contains("\"ts\": 2.000"));
        let _ = a;
    }

    #[test]
    fn metrics_json_shape() {
        let reg = run().metrics_registry();
        let j = reg.to_json();
        assert!(j.contains("\"wire_bytes.internode\": {\"type\": \"counter\", \"value\": 64}"));
        assert!(j.contains("\"schema_version\": 1"));
    }

    #[test]
    fn escaping_covers_controls() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
