//! The unified communicator API.
//!
//! The paper's central performance claim (§3.3.3, Figs. 7–12) is that
//! the *right* algorithm for a compression-enabled collective depends
//! on message size, rank count, and compression policy: the ring
//! Allreduce is bandwidth-optimal but pays `2(N−1)` compression-kernel
//! floors on `D/N` chunks, while gZ-ReDoub pays only `⌈log₂N⌉`
//! whole-vector kernels — so ring wins large messages and recursive
//! doubling wins small messages and large scales. That selection logic
//! belongs to the framework, not to every call site; NCCL and MPI both
//! expose communicator objects for exactly this reason.
//!
//! This module is the single seam between applications and the
//! collective algorithms:
//!
//! * [`Communicator`] (built via [`CommBuilder`]) owns the simulated
//!   cluster ([`crate::coordinator::ClusterSpec`]) and exposes
//!   `allreduce / allgather / reduce_scatter / scatter / bcast`
//!   methods, each taking a [`CollectiveSpec`] (root + algorithm hint).
//!   Scatter/Bcast accept any root; the binomial trees rotate the rank
//!   space around it.
//! * [`Tuner`] implements the crossover model: given the op, the
//!   [`crate::coordinator::ExecPolicy`], the message size and the
//!   [`crate::net::Topology`], it picks the
//!   [`crate::collectives::Algo`] — a three-way flat-ring /
//!   hierarchical / gZ-ReDoub decision on compressed multi-node
//!   layouts, the classic two-way switch elsewhere, and an explicit
//!   [`crate::collectives::Algo::Identity`] no-op for single-rank
//!   communicators. Callers can bypass it with [`AlgoHint::Force`].
//! * [`AlgoRegistry`] maps `(Op, Algo)` to the concrete collective free
//!   functions in [`crate::collectives`], which remain the registry's
//!   internals — no call site outside this module and `collectives`
//!   invokes them directly.
//!
//! **Accuracy as a selection axis.** A communicator built with
//! [`CommBuilder::accuracy_target`] carries a
//! [`crate::accuracy::BudgetPlan`]: the planner inverts the
//! error-propagation model to derive the per-call compressor bound,
//! [`Tuner::select_within_budget`] vetoes any algorithm whose stage
//! count would blow the budget (falling back to a compliant one), and
//! forced hints are validated against the plan. Each compressed
//! dispatch over real payloads additionally records predicted-vs-
//! observed error telemetry ([`CollectiveReport`]`::accuracy`).
//!
//! **The ExecPlan contract.** Every dispatch compiles a
//! [`crate::topo::ExecPlan`] — one compression-mode + error-bound
//! directive per schedule leg (flat algorithms are degenerate one-leg
//! plans) — and the executor enforces exactly it: under a budget the
//! per-tier split of [`crate::accuracy::split_across_tiers`] is
//! load-bearing, with tier 1 and tier 2 legs running different
//! compressor bounds, and the per-leg observed errors come back in
//! [`CollectiveReport::legs`]. With [`CommBuilder::adaptive`]`(true)`
//! an [`AdaptiveController`] closes the loop: telemetry headroom
//! relaxes the next dispatch's bounds (≤ 8×/step, every leg clamped at
//! the certified per-call budget), and a violation snaps back to the
//! certified plan.
//!
//! Every dispatch is recorded in the per-rank
//! [`crate::coordinator::OpCounters`] (`algo_selected`,
//! `tuner_decisions`, `predicted_err_bound`, `observed_max_err`) so
//! tests can assert the tuner's decisions and the error telemetry.

pub mod communicator;
pub mod registry;
pub mod tuner;

pub use communicator::{
    AdaptiveController, CollectiveReport, CommBuilder, Communicator, LegReport,
};
pub use registry::AlgoRegistry;
pub use tuner::{AlgoHint, CollectiveSpec, Tuner};

// The non-blocking/persistent surface lives in [`crate::pipeline`];
// re-exported here because `Communicator` methods return these types.
pub use crate::pipeline::{CollectiveHandle, PersistentColl, Pipeline};
