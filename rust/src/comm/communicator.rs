//! The [`Communicator`]: NCCL/MPI-style entry point for collectives.

use crate::accuracy::{
    complies, plan_auto, predict_worst, AccuracyReport, AccuracyTarget, BudgetPlan, ErrorProbe,
};
use crate::collectives::{Algo, Op};
use crate::compress::CompressionProfile;
use crate::coordinator::{
    run_collective, ClusterSpec, CompressionMode, DeviceBuf, ExecPolicy, RunReport,
};
use crate::error::{Error, Result};
use crate::net::Topology;

use super::registry::AlgoRegistry;
use super::tuner::{AlgoHint, CollectiveSpec, Tuner};

/// Builder for a [`Communicator`].
///
/// Assembles a [`ClusterSpec`] from primitives (rank count, policy,
/// error bound, compression profile, node layout) with paper-testbed
/// defaults; use [`Communicator::from_spec`] when a fully-formed spec
/// already exists (e.g. from [`crate::config::ClusterConfig`]).
#[derive(Debug, Clone)]
pub struct CommBuilder {
    ranks: usize,
    gpus_per_node: usize,
    policy: ExecPolicy,
    error_bound: Option<f64>,
    accuracy_target: Option<AccuracyTarget>,
    iterations: usize,
    profile: Option<CompressionProfile>,
    tuner: Option<Tuner>,
}

impl CommBuilder {
    /// A builder over `ranks` simulated GPUs (4 per node, full gZCCL
    /// policy, testbed defaults).
    pub fn new(ranks: usize) -> Self {
        CommBuilder {
            ranks,
            gpus_per_node: 4,
            policy: ExecPolicy::gzccl(),
            error_bound: None,
            accuracy_target: None,
            iterations: 1,
            profile: None,
            tuner: None,
        }
    }

    /// Select the execution-policy variant.
    pub fn policy(mut self, policy: ExecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Absolute error bound for the error-bounded compressor. Mutually
    /// exclusive with [`CommBuilder::accuracy_target`], which *derives*
    /// the bound instead.
    pub fn error_bound(mut self, eb: f64) -> Self {
        self.error_bound = Some(eb);
        self
    }

    /// End-to-end accuracy target — the alternative to a raw
    /// [`CommBuilder::error_bound`]. At [`CommBuilder::build`] the
    /// [`crate::accuracy::budget`] planner inverts the propagation
    /// model (anchored on the best-accuracy Allreduce schedule the
    /// topology supports, split across [`CommBuilder::iterations`]) to
    /// derive the per-call compressor bound, and every subsequent
    /// dispatch enforces the budget: the tuner vetoes non-compliant
    /// algorithms and forced hints are validated against the plan.
    pub fn accuracy_target(mut self, target: AccuracyTarget) -> Self {
        self.accuracy_target = Some(target);
        self
    }

    /// Number of dependent collective calls the accuracy target is
    /// split across (DDP steps, stacking batches). Default 1.
    pub fn iterations(mut self, iters: usize) -> Self {
        self.iterations = iters;
        self
    }

    /// Compressed-size profile for virtual-payload runs.
    pub fn compression_profile(mut self, profile: CompressionProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    /// GPUs per node (topology layout).
    pub fn gpus_per_node(mut self, g: usize) -> Self {
        self.gpus_per_node = g;
        self
    }

    /// Override the tuner (custom crossover knees).
    pub fn tuner(mut self, tuner: Tuner) -> Self {
        self.tuner = Some(tuner);
        self
    }

    /// Build the communicator. With an accuracy target set, this is
    /// where the budget planner runs: a fixed-rate policy is rejected
    /// outright (its error is unbounded — the hazard the planner
    /// exists to refuse), an uncompressed policy trivially satisfies
    /// any target, and the error-bounded policy gets its per-call `eb`
    /// derived from the target.
    pub fn build(self) -> Result<Communicator> {
        let topo = Topology::new(self.ranks, self.gpus_per_node)?;
        let mut plan: Option<BudgetPlan> = None;
        if let Some(target) = self.accuracy_target {
            match self.policy.compression {
                CompressionMode::None => {} // lossless: target trivially met
                CompressionMode::FixedRate | CompressionMode::ErrorBounded => {
                    if self.error_bound.is_some() {
                        return Err(Error::config(
                            "set either .error_bound() or .accuracy_target(), not both",
                        ));
                    }
                    plan = Some(plan_auto(
                        target,
                        self.iterations,
                        &topo,
                        self.policy.compression,
                    )?);
                }
            }
        }
        let mut spec = ClusterSpec::with_topology(topo, self.policy);
        if let Some(eb) = self.error_bound {
            spec.error_bound = eb;
        }
        if let Some(p) = &plan {
            spec.error_bound = p.eb;
        }
        if let Some(p) = self.profile {
            spec.profile = p;
        }
        Ok(Communicator {
            spec,
            tuner: self.tuner.unwrap_or_default(),
            plan,
        })
    }
}

/// Result of one communicator-dispatched collective: the underlying
/// [`RunReport`] plus what was dispatched and why.
#[derive(Debug, Clone)]
pub struct CollectiveReport {
    /// The operation that ran.
    pub op: Op,
    /// The algorithm that realized it.
    pub algo: Algo,
    /// Whether the [`Tuner`] chose the algorithm (`AlgoHint::Auto`) as
    /// opposed to a forced hint.
    pub auto_tuned: bool,
    /// Accuracy telemetry: predicted worst-case bound vs observed max
    /// deviation on a deterministic element sample. `Some` only for
    /// compressed collectives over real payloads (see
    /// [`crate::accuracy::telemetry`]).
    pub accuracy: Option<AccuracyReport>,
    /// The underlying run report.
    pub report: RunReport,
}

impl std::ops::Deref for CollectiveReport {
    type Target = RunReport;
    fn deref(&self) -> &RunReport {
        &self.report
    }
}

/// A communicator over a simulated cluster: owns the
/// [`ClusterSpec`] + [`Tuner`] and dispatches collectives through the
/// [`AlgoRegistry`].
#[derive(Clone)]
pub struct Communicator {
    spec: ClusterSpec,
    tuner: Tuner,
    plan: Option<BudgetPlan>,
}

impl Communicator {
    /// Start a [`CommBuilder`] over `ranks` GPUs.
    pub fn builder(ranks: usize) -> CommBuilder {
        CommBuilder::new(ranks)
    }

    /// Wrap an existing [`ClusterSpec`] (default tuner, no budget).
    pub fn from_spec(spec: ClusterSpec) -> Self {
        Communicator {
            spec,
            tuner: Tuner::default(),
            plan: None,
        }
    }

    /// The active error-budget plan, if the communicator was built with
    /// [`CommBuilder::accuracy_target`] under a compressed policy.
    pub fn budget_plan(&self) -> Option<&BudgetPlan> {
        self.plan.as_ref()
    }

    /// Communicator size.
    pub fn nranks(&self) -> usize {
        self.spec.topo.ranks()
    }

    /// The active variant policy.
    pub fn policy(&self) -> ExecPolicy {
        self.spec.policy
    }

    /// The underlying cluster spec.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    /// The tuner in use.
    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }

    /// Elementwise-sum Allreduce of `inputs[r]` on every rank.
    pub fn allreduce(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0);
        self.dispatch(Op::Allreduce, inputs, bytes, 0, spec)
    }

    /// Allgather: rank r contributes `inputs[r]` as block r; every rank
    /// returns the concatenation of all blocks.
    pub fn allgather(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        // Tune on the gathered volume, the quantity that crosses wires.
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0) * self.nranks().max(1);
        self.dispatch(Op::Allgather, inputs, bytes, 0, spec)
    }

    /// Ring Reduce_scatter: rank r returns the fully-reduced chunk r.
    pub fn reduce_scatter(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0);
        self.dispatch(Op::ReduceScatter, inputs, bytes, 0, spec)
    }

    /// One-to-all Scatter from `spec.root` (any rank):
    /// `inputs[spec.root]` holds the full vector (ignored elsewhere);
    /// rank r returns block r of the `Chunks::new(total, n)` layout.
    pub fn scatter(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let total_elems = inputs.get(spec.root).map(|b| b.elems()).unwrap_or(0);
        self.dispatch(Op::Scatter, inputs, total_elems * 4, total_elems, spec)
    }

    /// One-to-all Broadcast from `spec.root` (any rank): every rank
    /// returns the root's vector.
    pub fn bcast(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let bytes = inputs.get(spec.root).map(|b| b.bytes()).unwrap_or(0);
        self.dispatch(Op::Bcast, inputs, bytes, 0, spec)
    }

    fn dispatch(
        &self,
        op: Op,
        inputs: Vec<DeviceBuf>,
        msg_bytes: usize,
        total_elems: usize,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        if spec.root >= self.nranks() {
            return Err(Error::collective(format!(
                "{op:?}: root {} out of range for a {}-rank communicator",
                spec.root,
                self.nranks()
            )));
        }
        let (algo, auto_tuned) = match spec.hint {
            AlgoHint::Force(algo) => {
                if !AlgoRegistry::is_supported(op, algo) {
                    return Err(Error::collective(format!(
                        "forced {algo:?} is not implemented for {op:?} (supported: {:?})",
                        AlgoRegistry::supported(op)
                    )));
                }
                // A forced hint bypasses the tuner, not the budget: an
                // algorithm whose stage count blows the planned bound
                // is rejected instead of silently missing the target.
                if let Some(plan) = &self.plan {
                    if !complies(plan, op, algo, &self.spec.topo, spec.root) {
                        return Err(Error::budget(format!(
                            "forced {algo:?} rejected by the accuracy budget: its worst-case \
                             error exceeds the per-call bound {:.3e} (planned eb {:.3e})",
                            plan.per_call_abs, plan.eb
                        )));
                    }
                }
                (algo, false)
            }
            AlgoHint::Auto => {
                let algo = match &self.plan {
                    Some(plan) => self.tuner.select_within_budget(
                        op,
                        self.spec.policy,
                        &self.spec.topo,
                        msg_bytes,
                        spec.root,
                        plan,
                    )?,
                    None => self.tuner.select_with_topology(
                        op,
                        self.spec.policy,
                        &self.spec.topo,
                        msg_bytes,
                    ),
                };
                (algo, true)
            }
        };
        // Telemetry probe: sample the exact reference before the inputs
        // are consumed (compressed collectives on real payloads only).
        let probe = if self.spec.policy.compression != CompressionMode::None {
            ErrorProbe::prepare(op, &inputs, spec.root)
        } else {
            None
        };
        let program = AlgoRegistry::resolve(op, algo, total_elems, spec.root)?;
        let mut report = run_collective(&self.spec, inputs, &*program)?;
        let accuracy = probe
            .and_then(|p| p.observe(&report.outputs))
            .and_then(|obs| {
                predict_worst(
                    op,
                    algo,
                    &self.spec.topo,
                    spec.root,
                    self.spec.policy.compression,
                    self.spec.error_bound,
                )
                .map(|prediction| AccuracyReport {
                    prediction,
                    observed_max_err: obs.observed_max_err,
                    samples: obs.samples,
                    fp_slack: obs.fp_slack,
                })
            });
        // Record the dispatch decision (and the telemetry record) in
        // the per-rank counters so tests (and reports) can assert on it.
        for c in report.counters.iter_mut() {
            c.algo_selected = Some(algo);
            if auto_tuned {
                c.tuner_decisions += 1;
            }
            if let Some(a) = &accuracy {
                c.predicted_err_bound = a.prediction.bound();
                c.observed_max_err = Some(a.observed_max_err);
            }
        }
        Ok(CollectiveReport {
            op,
            algo,
            auto_tuned,
            accuracy,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Pcg32;

    fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    #[test]
    fn builder_defaults_and_overrides() {
        let comm = Communicator::builder(8)
            .policy(ExecPolicy::nccl())
            .error_bound(1e-3)
            .gpus_per_node(2)
            .build()
            .unwrap();
        assert_eq!(comm.nranks(), 8);
        assert_eq!(comm.cluster().topo.nodes(), 4);
        assert!((comm.cluster().error_bound - 1e-3).abs() < 1e-18);
        assert!(Communicator::builder(0).build().is_err());
    }

    #[test]
    fn allreduce_dispatch_records_decision() {
        let comm = Communicator::builder(4).build().unwrap();
        let inputs = real_inputs(4, 64, 5);
        let out = comm.allreduce(inputs, &CollectiveSpec::auto()).unwrap();
        assert_eq!(out.op, Op::Allreduce);
        assert!(out.auto_tuned);
        for c in &out.counters {
            assert_eq!(c.algo_selected, Some(out.algo));
            assert_eq!(c.tuner_decisions, 1);
        }
        // Small message → the tuner picks recursive doubling.
        assert_eq!(out.algo, Algo::RecursiveDoubling);
    }

    #[test]
    fn forced_hint_bypasses_tuner() {
        let comm = Communicator::builder(4).build().unwrap();
        let out = comm
            .allreduce(real_inputs(4, 64, 6), &CollectiveSpec::forced(Algo::Ring))
            .unwrap();
        assert_eq!(out.algo, Algo::Ring);
        assert!(!out.auto_tuned);
        for c in &out.counters {
            assert_eq!(c.algo_selected, Some(Algo::Ring));
            assert_eq!(c.tuner_decisions, 0);
        }
    }

    #[test]
    fn unsupported_force_and_bad_root_rejected() {
        let comm = Communicator::builder(4).build().unwrap();
        assert!(comm
            .allreduce(real_inputs(4, 8, 7), &CollectiveSpec::forced(Algo::Bruck))
            .is_err());
        // Identity is the tuner's internal no-op decision, not forceable.
        assert!(comm
            .allreduce(real_inputs(4, 8, 7), &CollectiveSpec::forced(Algo::Identity))
            .is_err());
        // Roots outside the communicator are rejected...
        let inputs: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Real(vec![1.0])).collect();
        assert!(comm
            .bcast(inputs, &CollectiveSpec::auto().with_root(4))
            .is_err());
    }

    #[test]
    fn bcast_and_scatter_work_from_every_root() {
        let n = 4;
        let d = 64;
        let comm = Communicator::builder(n).build().unwrap();
        let mut rng = Pcg32::seeded(91);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let chunks = crate::collectives::Chunks::new(d, n);
        for root in 0..n {
            let rooted = || -> Vec<DeviceBuf> {
                (0..n)
                    .map(|r| {
                        if r == root {
                            DeviceBuf::Real(full.clone())
                        } else {
                            DeviceBuf::Real(vec![])
                        }
                    })
                    .collect()
            };
            let spec = CollectiveSpec::auto().with_root(root);
            let bc = comm.bcast(rooted(), &spec).unwrap();
            for (r, out) in bc.outputs.iter().enumerate() {
                let tol = if r == root { 0.0 } else { 1.1e-4 };
                for (a, b) in out.as_real().iter().zip(&full) {
                    assert!((a - b).abs() <= tol, "bcast root {root} rank {r}");
                }
            }
            let sc = comm.scatter(rooted(), &spec).unwrap();
            for r in 0..n {
                let want = &full[chunks.range(r)];
                let got = sc.outputs[r].as_real();
                assert_eq!(got.len(), want.len(), "scatter root {root} rank {r}");
                for (a, b) in got.iter().zip(want) {
                    assert!((a - b).abs() <= 1.1e-4, "scatter root {root} rank {r}");
                }
            }
        }
    }

    #[test]
    fn single_rank_communicator_reports_identity() {
        let comm = Communicator::builder(1).build().unwrap();
        let out = comm
            .allreduce(vec![DeviceBuf::Real(vec![1.0, 2.0])], &CollectiveSpec::auto())
            .unwrap();
        assert_eq!(out.algo, Algo::Identity);
        assert_eq!(out.outputs[0].as_real(), &[1.0, 2.0]);
        assert_eq!(out.counters[0].algo_selected, Some(Algo::Identity));
    }

    #[test]
    fn scatter_derives_layout_from_root_input() {
        let n = 4;
        let d = 64;
        let mut rng = Pcg32::seeded(31);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let mut inputs = vec![DeviceBuf::Real(full.clone())];
        for _ in 1..n {
            inputs.push(DeviceBuf::Real(vec![]));
        }
        let comm = Communicator::builder(n).policy(ExecPolicy::nccl()).build().unwrap();
        let out = comm.scatter(inputs, &CollectiveSpec::auto()).unwrap();
        assert_eq!(out.algo, Algo::Binomial);
        let chunks = crate::collectives::Chunks::new(d, n);
        for r in 0..n {
            assert_eq!(out.outputs[r].as_real(), &full[chunks.range(r)]);
        }
    }

    #[test]
    fn accuracy_target_plans_the_error_bound() {
        use crate::accuracy::AccuracyTarget;
        let comm = Communicator::builder(8)
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .unwrap();
        let plan = comm.budget_plan().expect("compressed policy must plan");
        // 2 nodes → hierarchical anchor, one internode exchange: m = 1.
        assert_eq!(plan.amplification, 1.0);
        assert!((comm.cluster().error_bound - 1e-3).abs() < 1e-15);
        // Both knobs at once is a config error.
        assert!(Communicator::builder(8)
            .error_bound(1e-4)
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .is_err());
        // Fixed-rate policy: the planner rejects the unbounded hazard.
        assert!(Communicator::builder(8)
            .policy(ExecPolicy::cprp2p())
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .is_err());
        // Uncompressed policy: trivially met, no plan, no veto.
        let nc = Communicator::builder(8)
            .policy(ExecPolicy::nccl())
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .unwrap();
        assert!(nc.budget_plan().is_none());
    }

    #[test]
    fn telemetry_attached_for_compressed_real_runs() {
        let comm = Communicator::builder(4).error_bound(1e-3).build().unwrap();
        let out = comm
            .allreduce(real_inputs(4, 256, 9), &CollectiveSpec::auto())
            .unwrap();
        let acc = out
            .accuracy
            .expect("telemetry must run on real compressed payloads");
        assert_eq!(acc.within_bound(), Some(true), "observed {acc:?}");
        assert!(acc.samples > 0);
        for c in &out.counters {
            assert_eq!(c.observed_max_err, Some(acc.observed_max_err));
            assert!(c.predicted_err_bound.is_some());
        }
        // Virtual payloads: no telemetry (nothing real to compare).
        let virt: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Virtual(256)).collect();
        let vr = comm.allreduce(virt, &CollectiveSpec::auto()).unwrap();
        assert!(vr.accuracy.is_none());
        assert!(vr.counters[0].observed_max_err.is_none());
        // Uncompressed policies: no telemetry either.
        let nc = Communicator::builder(4).policy(ExecPolicy::nccl()).build().unwrap();
        assert!(nc
            .allreduce(real_inputs(4, 64, 9), &CollectiveSpec::auto())
            .unwrap()
            .accuracy
            .is_none());
    }

    #[test]
    fn all_ops_run_through_the_communicator() {
        let n = 4;
        let d = 128;
        let comm = Communicator::builder(n)
            .error_bound(1e-3)
            .build()
            .unwrap();
        let spec = CollectiveSpec::auto();
        assert!(comm.allreduce(real_inputs(n, d, 1), &spec).is_ok());
        assert!(comm.allgather(real_inputs(n, d, 2), &spec).is_ok());
        assert!(comm.reduce_scatter(real_inputs(n, d, 3), &spec).is_ok());
        let rooted = |seed| {
            let mut v = real_inputs(1, d, seed);
            for _ in 1..n {
                v.push(DeviceBuf::Real(vec![]));
            }
            v
        };
        assert!(comm.scatter(rooted(4), &spec).is_ok());
        assert!(comm.bcast(rooted(5), &spec).is_ok());
    }
}
