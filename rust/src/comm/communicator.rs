//! The [`Communicator`]: NCCL/MPI-style entry point for collectives.

use std::sync::{Arc, Mutex};

use crate::accuracy::{
    complies_tiers, plan_auto_tiers, predict_worst_tiers, split_across_tiers, AccuracyReport,
    AccuracyTarget, BudgetPlan, ErrorPrediction, ErrorProbe, TieredPlan,
};
use crate::collectives::{Algo, Op, MAX_PIPELINE_DEPTH};
use crate::compress::{CodecSpec, CompressionProfile};
use crate::coordinator::{
    run_collective, ClusterSpec, CompressionMode, DeviceBuf, ExecBackend, ExecPolicy, RunReport,
};
use crate::error::{Error, Result};
use crate::net::Topology;
use crate::obs::analysis::TraceAnalysis;
use crate::obs::calibrate::{self, Calibration};
use crate::obs::{TraceRun, TraceSummary, Tracer};
use crate::pipeline::{choose_depth, CollectiveHandle, PersistentColl, Pipeline};
use crate::topo::{
    compile_min_error, compile_rooted, estimate_flat_allgather, estimate_flat_redoub,
    estimate_flat_reduce_scatter, estimate_flat_ring, CostModel, ExecPlan, LegExec, LegKind,
    Schedule, TierTree,
};

use super::registry::AlgoRegistry;
use super::tuner::{AlgoHint, CollectiveSpec, Tuner};

/// Builder for a [`Communicator`].
///
/// Assembles a [`ClusterSpec`] from primitives (rank count, policy,
/// error bound, compression profile, node layout) with paper-testbed
/// defaults; use [`Communicator::from_spec`] when a fully-formed spec
/// already exists (e.g. from [`crate::config::ClusterConfig`]).
#[derive(Debug, Clone)]
pub struct CommBuilder {
    ranks: usize,
    gpus_per_node: usize,
    tiers: Option<Vec<usize>>,
    policy: ExecPolicy,
    error_bound: Option<f64>,
    codec: Option<CodecSpec>,
    accuracy_target: Option<AccuracyTarget>,
    external_plan: Option<BudgetPlan>,
    adaptive: bool,
    value_range: Option<f64>,
    iterations: usize,
    profile: Option<CompressionProfile>,
    tuner: Option<Tuner>,
    backend: Option<ExecBackend>,
    trace: Option<Tracer>,
    calibrate: Option<Arc<TraceRun>>,
    pipeline: Pipeline,
}

impl CommBuilder {
    /// A builder over `ranks` simulated GPUs (4 per node, full gZCCL
    /// policy, testbed defaults).
    pub fn new(ranks: usize) -> Self {
        CommBuilder {
            ranks,
            gpus_per_node: 4,
            tiers: None,
            policy: ExecPolicy::gzccl(),
            error_bound: None,
            codec: None,
            accuracy_target: None,
            external_plan: None,
            adaptive: false,
            value_range: None,
            iterations: 1,
            profile: None,
            tuner: None,
            backend: None,
            trace: None,
            calibrate: None,
            pipeline: Pipeline::Auto,
        }
    }

    /// Chunk-level pipelining policy for scheduled (hierarchical)
    /// dispatches: [`Pipeline::Auto`] (default) sweeps depths with the
    /// cost model, [`Pipeline::Off`] pins the barrier executor,
    /// [`Pipeline::Fixed`] pins an explicit depth.
    pub fn pipeline(mut self, pipeline: Pipeline) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Select the execution-policy variant.
    pub fn policy(mut self, policy: ExecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Absolute error bound for the error-bounded compressor. Mutually
    /// exclusive with [`CommBuilder::accuracy_target`], which *derives*
    /// the bound instead.
    pub fn error_bound(mut self, eb: f64) -> Self {
        self.error_bound = Some(eb);
        self
    }

    /// Ambient staged codec ([`CodecSpec`]) for every compressed leg.
    /// Overrides the mode's canonical compressor *and* the tuner's
    /// per-leg codec picks at dispatch; the compression mode follows
    /// the codec's family (a fixed-rate codec implies the fixed-rate
    /// policy mode). Parse CLI forms with [`CodecSpec::parse`].
    pub fn codec(mut self, codec: CodecSpec) -> Self {
        self.codec = Some(codec);
        self
    }

    /// End-to-end accuracy target — the alternative to a raw
    /// [`CommBuilder::error_bound`]. At [`CommBuilder::build`] the
    /// [`crate::accuracy::budget`] planner inverts the propagation
    /// model (anchored on the best-accuracy Allreduce schedule the
    /// topology supports, split across [`CommBuilder::iterations`]) to
    /// derive the per-call compressor bound, and every subsequent
    /// dispatch enforces the budget: the tuner vetoes non-compliant
    /// algorithms and forced hints are validated against the plan.
    pub fn accuracy_target(mut self, target: AccuracyTarget) -> Self {
        self.accuracy_target = Some(target);
        self
    }

    /// Adopt an externally-computed [`BudgetPlan`] instead of letting
    /// [`CommBuilder::accuracy_target`] derive one: applications that
    /// pin a specific algorithm invert the propagation model for *that*
    /// algorithm ([`crate::accuracy::plan_for_algo`]) and hand the
    /// result over, so dispatch-time budget validation, per-tier
    /// splits, and the adaptive controller all see the same certified
    /// plan. Mutually exclusive with both `.accuracy_target()` and
    /// `.error_bound()`; requires the error-bounded policy.
    pub fn budget_plan(mut self, plan: BudgetPlan) -> Self {
        self.external_plan = Some(plan);
        self
    }

    /// Close the telemetry adaptation loop: after every dispatch whose
    /// accuracy telemetry shows >2× headroom between the observed error
    /// and the **certified per-call budget**, relax the next dispatch's
    /// per-leg compressor bounds by half the headroom (≤
    /// [`crate::accuracy::MAX_EB_RELAXATION`]× per step), never letting
    /// any leg's bound exceed the certified per-call budget — and fall
    /// straight back to the certified plan if an observation ever
    /// exceeds it. Requires a budget (an accuracy target or an adopted
    /// plan) under the error-bounded policy; virtual payloads produce
    /// no telemetry and therefore never adapt.
    pub fn adaptive(mut self, on: bool) -> Self {
        self.adaptive = on;
        self
    }

    /// Number of dependent collective calls the accuracy target is
    /// split across (DDP steps, stacking batches). Default 1.
    pub fn iterations(mut self, iters: usize) -> Self {
        self.iterations = iters;
        self
    }

    /// Compressed-size profile for virtual-payload runs.
    pub fn compression_profile(mut self, profile: CompressionProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    /// GPUs per node (topology layout).
    pub fn gpus_per_node(mut self, g: usize) -> Self {
        self.gpus_per_node = g;
        self
    }

    /// Explicit multi-tier layout, innermost width first
    /// (`[gpus_per_node, nodes_per_rack, racks, …]` — the `--tiers
    /// 4x16x8` CLI form). Overrides [`CommBuilder::gpus_per_node`]:
    /// the first width *is* the GPUs per node. The widths must cover
    /// the rank count (one top group).
    pub fn tiers(mut self, widths: &[usize]) -> Self {
        self.tiers = Some(widths.to_vec());
        self
    }

    /// Payload value range, used to resolve a relative accuracy target
    /// ([`AccuracyTarget::RelError`]) into an absolute bound at plan
    /// time. Ignored by the self-contained target forms.
    pub fn value_range(mut self, range: f64) -> Self {
        self.value_range = Some(range);
        self
    }

    /// Override the tuner (custom crossover knees).
    pub fn tuner(mut self, tuner: Tuner) -> Self {
        self.tuner = Some(tuner);
        self
    }

    /// Attach a flight recorder ([`crate::obs::Tracer`]): every
    /// dispatched collective records its span tree (collective → leg →
    /// phase → codec stage), dispatch instants (tuner decisions, budget
    /// vetoes, eb relaxations), and wire/codec metrics into the shared
    /// sink. Disabled by default — without a tracer the execution path
    /// pays only an `Option` discriminant test per instrumentation
    /// site. Clones of the communicator share the tracer.
    pub fn trace(mut self, tracer: Tracer) -> Self {
        self.trace = Some(tracer);
        self
    }

    /// Select the execution backend ([`ExecBackend::Events`] by
    /// default): the event-driven engine scales to 10⁴–10⁵ ranks; the
    /// thread-per-rank runner is the reference oracle.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Calibrate the tuner's cost model from a previously recorded
    /// [`TraceRun`] (see [`crate::obs::calibrate`]): at
    /// [`CommBuilder::build`] the run's wire and kernel spans are
    /// least-squares fitted into per-tier effective links and per-codec
    /// kernel factors, and every subsequent dispatch prices schedules
    /// with the fitted model instead of the nameplate one.
    pub fn calibrate_from(mut self, run: Arc<TraceRun>) -> Self {
        self.calibrate = Some(run);
        self
    }

    /// Build the communicator. With an accuracy target set, this is
    /// where the budget planner runs: a fixed-rate policy is rejected
    /// outright (its error is unbounded — the hazard the planner
    /// exists to refuse), an uncompressed policy trivially satisfies
    /// any target, and the error-bounded policy gets its per-call `eb`
    /// derived from the target.
    pub fn build(self) -> Result<Communicator> {
        let tree = match &self.tiers {
            Some(widths) => TierTree::new(self.ranks, widths)?,
            None => TierTree::from(&Topology::new(self.ranks, self.gpus_per_node)?),
        };
        // An explicit codec decides the compression *family*: the mode
        // follows it so planning, dispatch, and propagation all see the
        // codec's actual semantics (fixed-rate codecs are the unbounded
        // family, everything else is error-bounded).
        let mut policy = self.policy;
        let mut codec = self.codec;
        if codec.is_some() && policy.compression == CompressionMode::None {
            return Err(Error::config(
                ".codec() needs a compressed policy (the uncompressed policy never \
                 builds a compressor)",
            ));
        }
        if let Some(c) = codec {
            policy.compression = LegExec::mode_for(c);
        }
        let mut plan: Option<BudgetPlan> = None;
        if let Some(target) = self.accuracy_target {
            if self.external_plan.is_some() {
                return Err(Error::config(
                    "set either .budget_plan() or .accuracy_target(), not both",
                ));
            }
            match policy.compression {
                CompressionMode::None => {} // lossless: target trivially met
                CompressionMode::FixedRate | CompressionMode::ErrorBounded => {
                    if self.error_bound.is_some() {
                        return Err(Error::config(
                            "set either .error_bound() or .accuracy_target(), not both",
                        ));
                    }
                    plan = Some(plan_auto_tiers(
                        target,
                        self.value_range,
                        self.iterations,
                        &tree,
                        policy.compression,
                    )?);
                    // Bitwise-exact target: instead of vetoing every
                    // compressed algorithm, bind the zero-distortion
                    // lossless codec tier — the run still compresses.
                    if target == AccuracyTarget::Bitexact {
                        codec = Some(CodecSpec::lossless());
                    }
                }
            }
        }
        if let Some(p) = self.external_plan {
            if self.error_bound.is_some() {
                return Err(Error::config(
                    "set either .error_bound() or .budget_plan(), not both",
                ));
            }
            if policy.compression != CompressionMode::ErrorBounded {
                return Err(Error::config(
                    ".budget_plan() needs the error-bounded compression policy \
                     (no other compressor can certify a plan)",
                ));
            }
            plan = Some(p);
        }
        // Build-time per-tier view of the budget (multi-tier trees).
        // Dispatch recompiles the split for each dispatched op and
        // *enforces* it leg by leg through the ExecPlan; this is the
        // Allreduce-anchored view applications introspect. A split
        // failure is a build error, not a silently-absent plan.
        let tiered = match &plan {
            Some(p) => Some(split_across_tiers(p, Op::Allreduce, &tree, None)?),
            None => None,
        };
        let adaptive = if self.adaptive {
            if plan.is_none() {
                return Err(Error::config(
                    ".adaptive(true) needs a certified budget to stay inside: set \
                     .accuracy_target() or adopt a .budget_plan() under a compressed policy",
                ));
            }
            Some(Arc::new(AdaptiveController::new()))
        } else {
            None
        };
        let mut spec = ClusterSpec::with_tiers(tree, policy);
        spec.codec = codec;
        if let Some(b) = self.backend {
            spec.backend = b;
        }
        if let Some(eb) = self.error_bound {
            spec.error_bound = eb;
        }
        if let Some(p) = &plan {
            spec.error_bound = p.eb;
        }
        if let Some(p) = self.profile {
            spec.profile = p;
        }
        spec.trace = self.trace;
        // Trace calibration: fit effective links + kernel factors from
        // the adopted run against this spec's nameplate parameters.
        let calibration = self
            .calibrate
            .map(|run| calibrate::calibrate(&run, &spec.gpu, &spec.tier_links()));
        Ok(Communicator {
            spec,
            tuner: self.tuner.unwrap_or_default(),
            plan,
            tiered,
            adaptive,
            calibration,
            pipeline: self.pipeline,
        })
    }
}

/// One leg of an executed plan, as reported back: where it ran, what
/// it did, the bound its compressor was held to, and the observed
/// compression error (real payloads only).
#[derive(Debug, Clone, Copy)]
pub struct LegReport {
    /// Leg index in execution order.
    pub leg: usize,
    /// Tier the leg ran within (0 for flat one-leg plans).
    pub tier: usize,
    /// The schedule leg's kind (`None` for flat plans — the leg is the
    /// whole collective).
    pub kind: Option<LegKind>,
    /// The directive the executor enforced (compression mode + eb).
    pub exec: LegExec,
    /// Max observed `|reconstructed − input|` over every rank's
    /// compress kernels on this leg (`None` for raw legs, virtual
    /// payloads, and buffers past
    /// [`crate::coordinator::LEG_PROBE_MAX_ELEMS`], whose O(n)
    /// roundtrip probe is skipped). For an error-bounded leg this must
    /// sit at or below `exec.eb` — the runtime proof the per-leg bound
    /// was enforced.
    pub observed_max_err: Option<f64>,
}

/// Result of one communicator-dispatched collective: the underlying
/// [`RunReport`] plus what was dispatched and why.
#[derive(Debug, Clone)]
pub struct CollectiveReport {
    /// The operation that ran.
    pub op: Op,
    /// The algorithm that realized it.
    pub algo: Algo,
    /// Whether the [`Tuner`] chose the algorithm (`AlgoHint::Auto`) as
    /// opposed to a forced hint.
    pub auto_tuned: bool,
    /// The compiled hierarchical schedule the dispatch executed
    /// (`Some` only for [`Algo::Hierarchical`]): its tree depth and
    /// per-tier legs are the tuner's per-tier decision record.
    pub schedule: Option<Schedule>,
    /// The execution plan the dispatch compiled and the executor
    /// enforced: one [`LegExec`] per leg (flat algorithms carry a
    /// degenerate one-leg plan). Under a budget its bounds are the
    /// per-tier split; under adaptation they carry the controller's
    /// current relaxation.
    pub exec_plan: ExecPlan,
    /// Per-leg breakdown: the plan's directives zipped with the
    /// observed per-leg compression errors.
    pub legs: Vec<LegReport>,
    /// Accuracy telemetry: predicted worst-case bound vs observed max
    /// deviation on a deterministic element sample. `Some` only for
    /// compressed collectives over real payloads (see
    /// [`crate::accuracy::telemetry`]).
    pub accuracy: Option<AccuracyReport>,
    /// The flight-recorder run captured for this dispatch: the full
    /// span trees and metrics of every rank, drained from the tracer
    /// the moment the collective finished. `Some` only when the
    /// communicator was built with [`CommBuilder::trace`].
    pub trace: Option<Arc<TraceRun>>,
    /// The underlying run report.
    pub report: RunReport,
}

impl CollectiveReport {
    /// One-glance digest of the captured trace (track/span/instant
    /// counts and the span-derived phase sums). `None` when the
    /// dispatch ran untraced.
    pub fn trace_summary(&self) -> Option<TraceSummary> {
        self.trace.as_ref().map(|t| t.summary())
    }

    /// Full trace analytics over this dispatch's captured run:
    /// critical path, bottleneck attribution, stragglers, and
    /// prediction residuals (see [`crate::obs::analysis`]). `None`
    /// when the dispatch ran untraced.
    pub fn analysis(&self) -> Option<TraceAnalysis> {
        self.trace.as_ref().map(|t| t.analyze())
    }
}

impl std::ops::Deref for CollectiveReport {
    type Target = RunReport;
    fn deref(&self) -> &RunReport {
        &self.report
    }
}

/// The telemetry→plan feedback state of an adaptive communicator
/// ([`CommBuilder::adaptive`]): a single relaxation factor (≥ 1)
/// applied to every planned per-leg bound at dispatch, grown from
/// observed headroom and reset to 1 the moment an observation exceeds
/// the certified per-call budget. Shared (via `Arc`) between clones of
/// the communicator, so repeated calls through any handle feed one
/// loop.
#[derive(Debug)]
pub struct AdaptiveController {
    scale: Mutex<f64>,
}

impl AdaptiveController {
    fn new() -> Self {
        AdaptiveController {
            scale: Mutex::new(1.0),
        }
    }

    /// Current relaxation factor over the planned bounds (1 = the
    /// certified plan, untouched).
    pub fn scale(&self) -> f64 {
        *self.scale.lock().expect("adaptive state poisoned")
    }

    /// Fold one dispatch's telemetry into the loop: back off to the
    /// certified plan on a budget violation, otherwise relax by the
    /// headroom between the observed error and the **certified
    /// per-call budget** ([`AccuracyReport::relaxation_factor_vs`] —
    /// half held in reserve, ≤ 8×/step), cumulatively capped so the
    /// planned eb never exceeds the per-call budget. Measuring against
    /// the fixed budget (not the eb-proportional prediction) is what
    /// makes the loop converge instead of chasing its own relaxation.
    fn update(&self, report: &AccuracyReport, plan: &BudgetPlan) {
        let mut s = self.scale.lock().expect("adaptive state poisoned");
        if report.observed_max_err > plan.per_call_abs * (1.0 + 1e-9) + report.fp_slack {
            *s = 1.0;
            return;
        }
        if let Some(f) = report.relaxation_factor_vs(plan.per_call_abs) {
            let cap = if plan.eb > 0.0 {
                (plan.per_call_abs / plan.eb).max(1.0)
            } else {
                1.0
            };
            *s = (*s * f).min(cap);
        }
    }
}

/// A communicator over a simulated cluster: owns the
/// [`ClusterSpec`] + [`Tuner`] and dispatches collectives through the
/// [`AlgoRegistry`].
#[derive(Clone)]
pub struct Communicator {
    spec: ClusterSpec,
    tuner: Tuner,
    plan: Option<BudgetPlan>,
    tiered: Option<TieredPlan>,
    adaptive: Option<Arc<AdaptiveController>>,
    calibration: Option<Calibration>,
    pipeline: Pipeline,
}

/// A fully-planned dispatch, frozen before execution: the algorithm,
/// the compiled schedule (scheduled algorithms), the enforced
/// [`ExecPlan`] (including pipeline depth), and the cost model that
/// priced them. [`Communicator::dispatch`] builds one per call;
/// [`Communicator::persistent`] builds one and reuses it across runs.
pub struct PlannedDispatch {
    pub(crate) op: Op,
    pub(crate) algo: Algo,
    pub(crate) auto_tuned: bool,
    pub(crate) schedule: Option<Schedule>,
    pub(crate) exec_plan: ExecPlan,
    pub(crate) root: usize,
    pub(crate) msg_bytes: usize,
    pub(crate) total_elems: usize,
    pub(crate) cost: CostModel,
}

impl Communicator {
    /// Start a [`CommBuilder`] over `ranks` GPUs.
    pub fn builder(ranks: usize) -> CommBuilder {
        CommBuilder::new(ranks)
    }

    /// Wrap an existing [`ClusterSpec`] (default tuner, no budget).
    pub fn from_spec(spec: ClusterSpec) -> Self {
        Communicator {
            spec,
            tuner: Tuner::default(),
            plan: None,
            tiered: None,
            adaptive: None,
            calibration: None,
            pipeline: Pipeline::Auto,
        }
    }

    /// This communicator with a different pipelining policy — the
    /// post-construction knob for [`Communicator::from_spec`] callers
    /// (the CLI's `--pipeline`); builder users set
    /// [`CommBuilder::pipeline`] instead.
    pub fn with_pipeline(mut self, pipeline: Pipeline) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// The active pipelining policy.
    pub fn pipeline_policy(&self) -> Pipeline {
        self.pipeline
    }

    /// The trace-fitted calibration in effect, when built with
    /// [`CommBuilder::calibrate_from`].
    pub fn calibration(&self) -> Option<&Calibration> {
        self.calibration.as_ref()
    }

    /// A clone of this communicator with a [`Calibration`] freshly
    /// fitted from `run` against this spec's nameplate parameters —
    /// the CLI's `--calibrate` rerun path. Equivalent to rebuilding
    /// with [`CommBuilder::calibrate_from`].
    pub fn recalibrated(&self, run: &TraceRun) -> Self {
        let mut c = self.clone();
        c.calibration = Some(calibrate::calibrate(
            run,
            &self.spec.gpu,
            &self.spec.tier_links(),
        ));
        c
    }

    /// The active error-budget plan, if the communicator was built with
    /// [`CommBuilder::accuracy_target`] under a compressed policy.
    pub fn budget_plan(&self) -> Option<&BudgetPlan> {
        self.plan.as_ref()
    }

    /// The adaptive controller, when built with
    /// [`CommBuilder::adaptive`]`(true)`.
    pub fn adaptive(&self) -> Option<&AdaptiveController> {
        self.adaptive.as_deref()
    }

    /// The compressor bound the next flat dispatch will run at: the
    /// planned per-call eb times the adaptive controller's current
    /// relaxation, clamped at the certified per-call budget. `None`
    /// without a budget plan or adaptive mode.
    pub fn adaptive_eb(&self) -> Option<f64> {
        let plan = self.plan.as_ref()?;
        let ctl = self.adaptive.as_ref()?;
        Some((plan.eb * ctl.scale()).min(plan.per_call_abs))
    }

    /// The per-tier split of the budget plan (multi-tier layouts under
    /// a budget; `None` when nothing compresses or no budget is set).
    pub fn tiered_plan(&self) -> Option<&TieredPlan> {
        self.tiered.as_ref()
    }

    /// The full multi-tier layout this communicator spans.
    pub fn tiers(&self) -> &TierTree {
        &self.spec.tiers
    }

    /// The analytic cost model the tuner prices schedules with at a
    /// given message size (device kernels, per-tier links, effective
    /// compression ratio). With a calibration adopted
    /// ([`CommBuilder::calibrate_from`]) the fitted per-tier links and
    /// per-codec kernel factors replace the nameplate values.
    fn cost_model(&self, msg_bytes: usize) -> CostModel {
        let base = CostModel::new(
            self.spec.gpu,
            self.spec.tier_links(),
            self.spec.profile.effective_ratio(msg_bytes.max(1)),
        );
        match &self.calibration {
            Some(cal) => cal.apply(&base),
            None => base,
        }
    }

    /// Analytic makespan of a flat algorithm on this cluster, where a
    /// closed-form estimator exists — used to price the tuner's
    /// rejected alternatives in the flight-recorder decision record.
    fn flat_estimate(
        &self,
        op: Op,
        algo: Algo,
        cost: &CostModel,
        msg_bytes: usize,
        compressed: bool,
    ) -> Option<f64> {
        let t = &self.spec.tiers;
        match (op, algo) {
            (Op::Allreduce, Algo::Ring) => {
                Some(estimate_flat_ring(t, cost, msg_bytes, compressed))
            }
            (Op::Allreduce, Algo::RecursiveDoubling) => {
                Some(estimate_flat_redoub(t, cost, msg_bytes, compressed))
            }
            (Op::ReduceScatter, Algo::Ring) => {
                Some(estimate_flat_reduce_scatter(t, cost, msg_bytes, compressed))
            }
            (Op::Allgather, Algo::Ring) => {
                Some(estimate_flat_allgather(t, cost, msg_bytes, compressed))
            }
            _ => None,
        }
    }

    /// Communicator size.
    pub fn nranks(&self) -> usize {
        self.spec.topo.ranks()
    }

    /// The active variant policy.
    pub fn policy(&self) -> ExecPolicy {
        self.spec.policy
    }

    /// The underlying cluster spec.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.spec
    }

    /// The tuner in use.
    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }

    /// Elementwise-sum Allreduce of `inputs[r]` on every rank.
    pub fn allreduce(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0);
        self.dispatch(Op::Allreduce, inputs, bytes, 0, spec)
    }

    /// Allgather: rank r contributes `inputs[r]` as block r; every rank
    /// returns the concatenation of all blocks.
    pub fn allgather(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        // Tune on the gathered volume, the quantity that crosses wires.
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0) * self.nranks().max(1);
        self.dispatch(Op::Allgather, inputs, bytes, 0, spec)
    }

    /// Ring Reduce_scatter: rank r returns the fully-reduced chunk r.
    pub fn reduce_scatter(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let bytes = inputs.first().map(|b| b.bytes()).unwrap_or(0);
        self.dispatch(Op::ReduceScatter, inputs, bytes, 0, spec)
    }

    /// One-to-all Scatter from `spec.root` (any rank):
    /// `inputs[spec.root]` holds the full vector (ignored elsewhere);
    /// rank r returns block r of the `Chunks::new(total, n)` layout.
    pub fn scatter(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let total_elems = inputs.get(spec.root).map(|b| b.elems()).unwrap_or(0);
        self.dispatch(Op::Scatter, inputs, total_elems * 4, total_elems, spec)
    }

    /// One-to-all Broadcast from `spec.root` (any rank): every rank
    /// returns the root's vector.
    pub fn bcast(
        &self,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        // Like Scatter, carry the root's element count: non-root ranks
        // hold empty inputs, so the rooted hierarchical descent cannot
        // derive the vector length locally.
        let total_elems = inputs.get(spec.root).map(|b| b.elems()).unwrap_or(0);
        self.dispatch(Op::Bcast, inputs, total_elems * 4, total_elems, spec)
    }

    /// Op-generic dispatch: run `op` over `inputs` with the same
    /// size/root derivation the five named wrappers use.
    pub fn collective(
        &self,
        op: Op,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        match op {
            Op::Allreduce => self.allreduce(inputs, spec),
            Op::Allgather => self.allgather(inputs, spec),
            Op::ReduceScatter => self.reduce_scatter(inputs, spec),
            Op::Scatter => self.scatter(inputs, spec),
            Op::Bcast => self.bcast(inputs, spec),
        }
    }

    /// Non-blocking dispatch: run `op` on a worker thread and return a
    /// waitable [`CollectiveHandle`] immediately, so the caller can
    /// overlap independent compute (a DDP backward pass) with the
    /// collective. Planning errors surface at
    /// [`CollectiveHandle::wait`].
    pub fn icollective(
        &self,
        op: Op,
        inputs: Vec<DeviceBuf>,
        spec: &CollectiveSpec,
    ) -> CollectiveHandle {
        let comm = self.clone();
        let spec = *spec;
        CollectiveHandle::spawn(move || comm.collective(op, inputs, &spec))
    }

    /// Plan `op` over `elems`-element payloads once — algorithm
    /// selection, schedule compilation, budget split, codec override,
    /// pipeline depth — and freeze the result in a [`PersistentColl`]
    /// whose `run`/`irun` skip all per-dispatch planning. `elems` is
    /// the per-rank payload length (for Scatter: the full vector length
    /// at the root), and must match the inputs later handed to `run`.
    pub fn persistent(
        &self,
        op: Op,
        elems: usize,
        spec: &CollectiveSpec,
    ) -> Result<PersistentColl> {
        let (msg_bytes, total_elems) = match op {
            // Tune on the gathered volume, as the wrapper does.
            Op::Allgather => (elems * 4 * self.nranks().max(1), 0),
            // Rooted ops carry the root's vector length explicitly.
            Op::Scatter | Op::Bcast => (elems * 4, elems),
            Op::Allreduce | Op::ReduceScatter => (elems * 4, 0),
        };
        let planned = self.plan_dispatch(op, msg_bytes, total_elems, spec)?;
        Ok(PersistentColl {
            comm: self.clone(),
            planned: Arc::new(planned),
        })
    }

    fn dispatch(
        &self,
        op: Op,
        inputs: Vec<DeviceBuf>,
        msg_bytes: usize,
        total_elems: usize,
        spec: &CollectiveSpec,
    ) -> Result<CollectiveReport> {
        let planned = self.plan_dispatch(op, msg_bytes, total_elems, spec)?;
        self.run_planned(&planned, inputs)
    }

    /// Plan one dispatch without running it: algorithm selection (or
    /// budget veto), schedule compilation, ExecPlan assembly (per-tier
    /// bounds, codec override) and pipeline-depth selection — the
    /// front half of [`Communicator::dispatch`], reused by
    /// [`Communicator::persistent`] to amortize planning across runs.
    pub(crate) fn plan_dispatch(
        &self,
        op: Op,
        msg_bytes: usize,
        total_elems: usize,
        spec: &CollectiveSpec,
    ) -> Result<PlannedDispatch> {
        if spec.root >= self.nranks() {
            return Err(Error::collective(format!(
                "{op:?}: root {} out of range for a {}-rank communicator",
                spec.root,
                self.nranks()
            )));
        }
        // One cost model per dispatch, shared by selection and schedule
        // compilation; the auto path reuses the schedule its selection
        // sweep already compiled.
        let cost = self.cost_model(msg_bytes);
        let (algo, auto_tuned, preselected) = match spec.hint {
            AlgoHint::Force(algo) => {
                if !AlgoRegistry::is_supported(op, algo) {
                    return Err(Error::collective(format!(
                        "forced {algo:?} is not implemented for {op:?} (supported: {:?})",
                        AlgoRegistry::supported(op)
                    )));
                }
                // A forced hint bypasses the tuner, not the budget: an
                // algorithm whose stage count blows the planned bound
                // is rejected instead of silently missing the target.
                if let Some(plan) = &self.plan {
                    if !complies_tiers(plan, op, algo, &self.spec.tiers, spec.root) {
                        return Err(Error::budget(format!(
                            "forced {algo:?} rejected by the accuracy budget: its worst-case \
                             error exceeds the per-call bound {:.3e} (planned eb {:.3e})",
                            plan.per_call_abs, plan.eb
                        )));
                    }
                }
                (algo, false, None)
            }
            AlgoHint::Auto => match &self.plan {
                Some(plan) => {
                    // The veto hands back the certified min-error
                    // schedule alongside its decision.
                    let (algo, sched) = self.tuner.select_within_budget_tiers(
                        op,
                        self.spec.policy,
                        &self.spec.tiers,
                        &cost,
                        msg_bytes,
                        spec.root,
                        plan,
                    )?;
                    (algo, true, sched)
                }
                None => {
                    let (algo, sched) = self.tuner.select_with_tiers_scheduled(
                        op,
                        self.spec.policy,
                        &self.spec.tiers,
                        &cost,
                        msg_bytes,
                    );
                    (algo, true, sched)
                }
            },
        };
        // Hierarchical dispatch runs a compiled schedule: cost-tuned
        // per-tier legs normally; under a budget, the min-error legs
        // the plan's amplification certified. The rooted descents
        // (Scatter/Bcast) compile around the dispatch root.
        let compressed = self.spec.policy.compression != CompressionMode::None;
        let schedule: Option<Schedule> = if algo == Algo::Hierarchical {
            Some(if matches!(op, Op::Scatter | Op::Bcast) {
                compile_rooted(op, &self.spec.tiers, compressed, spec.root)?
            } else {
                match (&self.plan, preselected) {
                    (Some(_), Some(s)) => s,
                    (Some(_), None) => compile_min_error(op, &self.spec.tiers, compressed)?,
                    (None, Some(s)) => s,
                    (None, None) => self.tuner.plan_schedule(
                        op,
                        self.spec.policy,
                        &self.spec.tiers,
                        &cost,
                        msg_bytes,
                    )?,
                }
            })
        } else {
            None
        };
        // Compile the ExecPlan — the single contract handed to the
        // executor. Budgeted hierarchical dispatch enforces the
        // per-tier split (tier 1 and tier 2 legs run different
        // compressors); everything else runs uniform bounds, and flat
        // algorithms become degenerate one-leg plans.
        let mut exec_plan = match &schedule {
            Some(s) => match &self.plan {
                Some(plan) => {
                    let split = split_across_tiers(plan, op, &self.spec.tiers, None)?;
                    ExecPlan::tiered(
                        s.clone(),
                        self.spec.policy.compression,
                        &split.tier_ebs(s.tree.depth()),
                        plan.eb,
                    )
                }
                None => ExecPlan::uniform(
                    s.clone(),
                    self.spec.policy.compression,
                    self.spec.error_bound,
                ),
            },
            None => ExecPlan::flat(op, self.spec.policy.compression, self.spec.error_bound),
        };
        // An explicit ambient codec beats the tuner's per-leg picks:
        // every compressed leg is re-pointed at it. The canonical cuszp
        // choice is a no-op (it IS the default), so tuned mixed-codec
        // plans survive exactly when nothing was overridden.
        if compressed {
            if let Some(c) = self.spec.codec {
                if c != CodecSpec::cuszp() {
                    exec_plan = exec_plan.with_codec(c);
                }
            }
        }
        // Pipeline depth is a tuned axis like algo/codec/eb: priced by
        // the same cost model via the pipelined makespan estimate.
        // Flat algorithms stay at depth 1 — only the leg interpreter
        // chunks.
        if let Some(s) = &schedule {
            let depth = match self.pipeline {
                Pipeline::Off => 1,
                Pipeline::Fixed(d) => d.min(MAX_PIPELINE_DEPTH),
                Pipeline::Auto => choose_depth(s, &self.spec.tiers, &cost, msg_bytes),
            };
            exec_plan = exec_plan.with_depth(depth);
        }
        Ok(PlannedDispatch {
            op,
            algo,
            auto_tuned,
            schedule,
            exec_plan,
            root: spec.root,
            msg_bytes,
            total_elems,
            cost,
        })
    }

    /// Execute a [`PlannedDispatch`]: the back half of
    /// [`Communicator::dispatch`] — adaptive relaxation, trace
    /// instants, telemetry probe, the run itself, and report assembly.
    pub(crate) fn run_planned(
        &self,
        planned: &PlannedDispatch,
        inputs: Vec<DeviceBuf>,
    ) -> Result<CollectiveReport> {
        let (op, algo, auto_tuned) = (planned.op, planned.algo, planned.auto_tuned);
        let schedule = &planned.schedule;
        let cost = &planned.cost;
        let msg_bytes = planned.msg_bytes;
        let compressed = self.spec.policy.compression != CompressionMode::None;
        let mut exec_plan = planned.exec_plan.clone();
        // Adaptation: fold the controller's current telemetry-earned
        // relaxation into the plan, every leg clamped at the certified
        // per-call budget.
        if let (Some(ctl), Some(plan)) = (&self.adaptive, &self.plan) {
            let scale = ctl.scale();
            if scale > 1.0 {
                exec_plan = exec_plan.relaxed(scale, plan.per_call_abs);
            }
        }
        // Flight recorder: the dispatch decision (with rejected
        // alternatives priced by the same cost model the tuner used)
        // and any budget vetoes, as instants at virtual t = 0.
        if let Some(tr) = &self.spec.trace {
            let rejected: Vec<String> = AlgoRegistry::supported(op)
                .iter()
                .filter(|a| **a != algo)
                .map(|a| match self.flat_estimate(op, *a, cost, msg_bytes, compressed) {
                    Some(est) => format!("{a:?}={est:.3e}s"),
                    None => format!("{a:?}"),
                })
                .collect();
            // Per-leg predictions from the very cost model selection
            // used: the analyzer joins these against observed leg spans
            // for the residual report, and the calibrator's acceptance
            // test re-predicts against the same addends.
            let pred_legs: Vec<String> = match &schedule {
                Some(s) => s
                    .leg_costs(&self.spec.tiers, cost, msg_bytes)
                    .iter()
                    .map(|c| format!("{c:.9e}"))
                    .collect(),
                None => self
                    .flat_estimate(op, algo, cost, msg_bytes, compressed)
                    .map(|e| vec![format!("{e:.9e}")])
                    .unwrap_or_default(),
            };
            let mut args = vec![
                ("op", format!("{op:?}")),
                ("algo", format!("{algo:?}")),
                (
                    "source",
                    if auto_tuned { "auto" } else { "forced" }.to_string(),
                ),
                ("rejected", rejected.join(", ")),
                ("depth", format!("{}", exec_plan.depth)),
            ];
            if !pred_legs.is_empty() {
                // Depth-1 prediction is the plain leg sum; pipelined
                // dispatches record the overlapped estimate the depth
                // chooser priced.
                let total: f64 = match (&schedule, exec_plan.depth) {
                    (Some(s), d) if d > 1 => s.estimate_makespan_pipelined(
                        &self.spec.tiers,
                        cost,
                        msg_bytes,
                        d,
                    ),
                    _ => pred_legs.iter().filter_map(|p| p.parse::<f64>().ok()).sum(),
                };
                args.push(("pred_legs", pred_legs.join("+")));
                args.push(("pred_makespan", format!("{total:.9e}")));
            }
            tr.instant("tuner-decision", 0.0, args);
            if let Some(plan) = &self.plan {
                let vetoed: Vec<String> = AlgoRegistry::supported(op)
                    .iter()
                    .filter(|a| !complies_tiers(plan, op, **a, &self.spec.tiers, planned.root))
                    .map(|a| format!("{a:?}"))
                    .collect();
                if !vetoed.is_empty() {
                    tr.instant(
                        "budget-veto",
                        0.0,
                        vec![
                            ("op", format!("{op:?}")),
                            ("per_call_abs", format!("{:.3e}", plan.per_call_abs)),
                            ("vetoed", vetoed.join(", ")),
                        ],
                    );
                }
            }
        }
        // Telemetry probe: sample the exact reference before the inputs
        // are consumed (compressed collectives on real payloads only).
        let probe = if compressed {
            ErrorProbe::prepare(op, &inputs, planned.root)
        } else {
            None
        };
        let program = AlgoRegistry::resolve_planned(
            op,
            algo,
            planned.total_elems,
            planned.root,
            Some(exec_plan.clone()),
        )?;
        let mut report = run_collective(&self.spec, inputs, &*program)?;
        // The error prediction follows the plan that actually ran:
        // scheduled plans walk their own legs at their own bounds
        // (`Σ_t A[t] · eb_t`), flat plans use the closed-form model at
        // their single leg's bound.
        let prediction = match self.spec.policy.compression {
            CompressionMode::None => Some(ErrorPrediction::Exact),
            CompressionMode::FixedRate => Some(ErrorPrediction::Unbounded),
            CompressionMode::ErrorBounded => match exec_plan.predicted_bound() {
                Some(b) => Some(if b == 0.0 {
                    ErrorPrediction::Exact
                } else {
                    ErrorPrediction::Bounded(b)
                }),
                None => predict_worst_tiers(
                    op,
                    algo,
                    &self.spec.tiers,
                    planned.root,
                    CompressionMode::ErrorBounded,
                    exec_plan.leg(0).eb,
                ),
            },
        };
        let accuracy = probe
            .and_then(|p| p.observe(&report.outputs))
            .and_then(|obs| {
                prediction.map(|prediction| AccuracyReport {
                    prediction,
                    observed_max_err: obs.observed_max_err,
                    samples: obs.samples,
                    fp_slack: obs.fp_slack,
                })
            });
        // Record the dispatch decision (and the telemetry record) in
        // the per-rank counters so tests (and reports) can assert on it.
        for c in report.counters.iter_mut() {
            c.algo_selected = Some(algo);
            if auto_tuned {
                c.tuner_decisions += 1;
            }
            if let Some(a) = &accuracy {
                c.predicted_err_bound = a.prediction.bound();
                c.observed_max_err = Some(a.observed_max_err);
            }
        }
        // Per-leg breakdown: the plan's directives zipped with the
        // observed per-leg compression errors the executor recorded.
        let legs: Vec<LegReport> = exec_plan
            .legs
            .iter()
            .enumerate()
            .map(|(i, ex)| LegReport {
                leg: i,
                tier: exec_plan.schedule.as_ref().map_or(0, |s| s.legs[i].tier),
                kind: exec_plan.schedule.as_ref().map(|s| s.legs[i].kind),
                exec: *ex,
                observed_max_err: report
                    .leg_errors
                    .iter()
                    .find(|l| l.leg == i)
                    .map(|l| l.observed_max_err),
            })
            .collect();
        // Close the adaptation loop: fold this dispatch's telemetry
        // into the controller for the next call. A traced dispatch
        // records any scale change as an eb-relaxation instant at the
        // collective's makespan (the virtual moment the telemetry
        // that earned it was observed).
        if let (Some(ctl), Some(plan)) = (&self.adaptive, &self.plan) {
            if let Some(a) = &accuracy {
                let before = ctl.scale();
                ctl.update(a, plan);
                let after = ctl.scale();
                if after != before {
                    if let Some(tr) = &self.spec.trace {
                        tr.instant(
                            "eb-relaxation",
                            report.makespan.as_secs(),
                            vec![
                                ("scale_before", format!("{before:.4}")),
                                ("scale_after", format!("{after:.4}")),
                                ("observed_max_err", format!("{:.3e}", a.observed_max_err)),
                                ("per_call_abs", format!("{:.3e}", plan.per_call_abs)),
                            ],
                        );
                    }
                }
            }
        }
        // Drain the flight recorder: everything the ranks flushed plus
        // the dispatch instants becomes this collective's TraceRun.
        let trace = self.spec.trace.as_ref().map(|tr| {
            tr.take_run(vec![
                ("op".to_string(), format!("{op:?}")),
                ("algo".to_string(), format!("{algo:?}")),
                (
                    "makespan_s".to_string(),
                    format!("{:.9e}", report.makespan.as_secs()),
                ),
            ])
        });
        Ok(CollectiveReport {
            op,
            algo,
            auto_tuned,
            schedule: planned.schedule.clone(),
            exec_plan,
            legs,
            accuracy,
            trace,
            report,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Pcg32;

    fn real_inputs(n: usize, d: usize, seed: u64) -> Vec<DeviceBuf> {
        (0..n)
            .map(|r| {
                let mut rng = Pcg32::new(seed, r as u64);
                DeviceBuf::Real(rng.uniform_vec(d, -1.0, 1.0))
            })
            .collect()
    }

    #[test]
    fn builder_defaults_and_overrides() {
        let comm = Communicator::builder(8)
            .policy(ExecPolicy::nccl())
            .error_bound(1e-3)
            .gpus_per_node(2)
            .build()
            .unwrap();
        assert_eq!(comm.nranks(), 8);
        assert_eq!(comm.cluster().topo.nodes(), 4);
        assert!((comm.cluster().error_bound - 1e-3).abs() < 1e-18);
        assert!(Communicator::builder(0).build().is_err());
    }

    #[test]
    fn allreduce_dispatch_records_decision() {
        let comm = Communicator::builder(4).build().unwrap();
        let inputs = real_inputs(4, 64, 5);
        let out = comm.allreduce(inputs, &CollectiveSpec::auto()).unwrap();
        assert_eq!(out.op, Op::Allreduce);
        assert!(out.auto_tuned);
        for c in &out.counters {
            assert_eq!(c.algo_selected, Some(out.algo));
            assert_eq!(c.tuner_decisions, 1);
        }
        // Small message → the tuner picks recursive doubling.
        assert_eq!(out.algo, Algo::RecursiveDoubling);
    }

    #[test]
    fn forced_hint_bypasses_tuner() {
        let comm = Communicator::builder(4).build().unwrap();
        let out = comm
            .allreduce(real_inputs(4, 64, 6), &CollectiveSpec::forced(Algo::Ring))
            .unwrap();
        assert_eq!(out.algo, Algo::Ring);
        assert!(!out.auto_tuned);
        for c in &out.counters {
            assert_eq!(c.algo_selected, Some(Algo::Ring));
            assert_eq!(c.tuner_decisions, 0);
        }
    }

    #[test]
    fn unsupported_force_and_bad_root_rejected() {
        let comm = Communicator::builder(4).build().unwrap();
        assert!(comm
            .allreduce(real_inputs(4, 8, 7), &CollectiveSpec::forced(Algo::Bruck))
            .is_err());
        // Identity is the tuner's internal no-op decision, not forceable.
        assert!(comm
            .allreduce(real_inputs(4, 8, 7), &CollectiveSpec::forced(Algo::Identity))
            .is_err());
        // Roots outside the communicator are rejected...
        let inputs: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Real(vec![1.0])).collect();
        assert!(comm
            .bcast(inputs, &CollectiveSpec::auto().with_root(4))
            .is_err());
    }

    #[test]
    fn bcast_and_scatter_work_from_every_root() {
        let n = 4;
        let d = 64;
        let comm = Communicator::builder(n).build().unwrap();
        let mut rng = Pcg32::seeded(91);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let chunks = crate::collectives::Chunks::new(d, n);
        for root in 0..n {
            let rooted = || -> Vec<DeviceBuf> {
                (0..n)
                    .map(|r| {
                        if r == root {
                            DeviceBuf::Real(full.clone())
                        } else {
                            DeviceBuf::Real(vec![])
                        }
                    })
                    .collect()
            };
            let spec = CollectiveSpec::auto().with_root(root);
            let bc = comm.bcast(rooted(), &spec).unwrap();
            for (r, out) in bc.outputs.iter().enumerate() {
                let tol = if r == root { 0.0 } else { 1.1e-4 };
                for (a, b) in out.as_real().iter().zip(&full) {
                    assert!((a - b).abs() <= tol, "bcast root {root} rank {r}");
                }
            }
            let sc = comm.scatter(rooted(), &spec).unwrap();
            for r in 0..n {
                let want = &full[chunks.range(r)];
                let got = sc.outputs[r].as_real();
                assert_eq!(got.len(), want.len(), "scatter root {root} rank {r}");
                for (a, b) in got.iter().zip(want) {
                    assert!((a - b).abs() <= 1.1e-4, "scatter root {root} rank {r}");
                }
            }
        }
    }

    #[test]
    fn single_rank_communicator_reports_identity() {
        let comm = Communicator::builder(1).build().unwrap();
        let out = comm
            .allreduce(vec![DeviceBuf::Real(vec![1.0, 2.0])], &CollectiveSpec::auto())
            .unwrap();
        assert_eq!(out.algo, Algo::Identity);
        assert_eq!(out.outputs[0].as_real(), &[1.0, 2.0]);
        assert_eq!(out.counters[0].algo_selected, Some(Algo::Identity));
    }

    #[test]
    fn scatter_derives_layout_from_root_input() {
        let n = 4;
        let d = 64;
        let mut rng = Pcg32::seeded(31);
        let full = rng.uniform_vec(d, -1.0, 1.0);
        let mut inputs = vec![DeviceBuf::Real(full.clone())];
        for _ in 1..n {
            inputs.push(DeviceBuf::Real(vec![]));
        }
        let comm = Communicator::builder(n).policy(ExecPolicy::nccl()).build().unwrap();
        let out = comm.scatter(inputs, &CollectiveSpec::auto()).unwrap();
        assert_eq!(out.algo, Algo::Binomial);
        let chunks = crate::collectives::Chunks::new(d, n);
        for r in 0..n {
            assert_eq!(out.outputs[r].as_real(), &full[chunks.range(r)]);
        }
    }

    #[test]
    fn accuracy_target_plans_the_error_bound() {
        use crate::accuracy::AccuracyTarget;
        let comm = Communicator::builder(8)
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .unwrap();
        let plan = comm.budget_plan().expect("compressed policy must plan");
        // 2 nodes → hierarchical anchor, one internode exchange: m = 1.
        assert_eq!(plan.amplification, 1.0);
        assert!((comm.cluster().error_bound - 1e-3).abs() < 1e-15);
        // Both knobs at once is a config error.
        assert!(Communicator::builder(8)
            .error_bound(1e-4)
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .is_err());
        // Fixed-rate policy: the planner rejects the unbounded hazard.
        assert!(Communicator::builder(8)
            .policy(ExecPolicy::cprp2p())
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .is_err());
        // Uncompressed policy: trivially met, no plan, no veto.
        let nc = Communicator::builder(8)
            .policy(ExecPolicy::nccl())
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .unwrap();
        assert!(nc.budget_plan().is_none());
    }

    #[test]
    fn bitexact_target_plans_lossless_and_roundtrips_bit_identical() {
        use crate::accuracy::AccuracyTarget;
        let n = 8;
        let d = 256;
        // Integer-valued payloads: every summation order yields the
        // same f32 bits, so the lossless run must match the exact
        // elementwise sum bit for bit.
        let int_inputs = || -> Vec<DeviceBuf> {
            (0..n)
                .map(|r| {
                    let mut rng = Pcg32::new(77, r as u64);
                    DeviceBuf::Real(
                        (0..d).map(|_| (rng.next_u32() % 17) as f32 - 8.0).collect(),
                    )
                })
                .collect()
        };
        let comm = Communicator::builder(n)
            .accuracy_target(AccuracyTarget::Bitexact)
            .build()
            .expect("bitexact target plans lossless instead of vetoing");
        let plan = comm.budget_plan().expect("a zero-budget plan is attached");
        assert_eq!(plan.eb, 0.0);
        assert_eq!(plan.per_call_abs, 0.0);
        assert_eq!(comm.cluster().codec, Some(CodecSpec::lossless()));
        let out = comm
            .allreduce(int_inputs(), &CollectiveSpec::forced(Algo::Hierarchical))
            .unwrap();
        // Every compressed leg ran the lossless pipeline at eb 0.
        assert!(out.legs.iter().any(|l| l.exec.compresses()));
        for l in out.legs.iter().filter(|l| l.exec.compresses()) {
            assert_eq!(l.exec.codec, CodecSpec::lossless());
            assert_eq!(l.exec.eb, 0.0);
        }
        // Bit-identical against the exact elementwise sum.
        let mut exact = vec![0.0f32; d];
        for buf in &int_inputs() {
            for (e, x) in exact.iter_mut().zip(buf.as_real()) {
                *e += x;
            }
        }
        for rank_out in &out.outputs {
            for (a, b) in rank_out.as_real().iter().zip(&exact) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        let acc = out.accuracy.expect("real compressed payloads probe");
        assert_eq!(acc.observed_max_err, 0.0);
        assert_eq!(acc.prediction, ErrorPrediction::Exact);
        assert!(out.report.leg_warnings.is_empty(), "{:?}", out.report.leg_warnings);
        // The flat ring a lossy budget vetoes complies at zero
        // distortion — no veto under the bitexact plan.
        assert!(comm
            .allreduce(int_inputs(), &CollectiveSpec::forced(Algo::Ring))
            .is_ok());
    }

    #[test]
    fn ambient_codec_overrides_every_compressed_leg() {
        let comm = Communicator::builder(8)
            .codec(CodecSpec::rle_rice())
            .error_bound(1e-3)
            .build()
            .unwrap();
        assert_eq!(comm.cluster().codec, Some(CodecSpec::rle_rice()));
        let out = comm
            .allreduce(
                real_inputs(8, 256, 13),
                &CollectiveSpec::forced(Algo::Hierarchical),
            )
            .unwrap();
        assert!(out.legs.iter().any(|l| l.exec.compresses()));
        for l in out.legs.iter().filter(|l| l.exec.compresses()) {
            assert_eq!(l.exec.codec, CodecSpec::rle_rice());
        }
        let acc = out.accuracy.expect("real compressed payloads probe");
        assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
        assert!(out.report.leg_warnings.is_empty(), "{:?}", out.report.leg_warnings);
        // A fixed-rate codec flips the policy family at build.
        let fr = Communicator::builder(8)
            .codec(CodecSpec::fixed_rate(12))
            .build()
            .unwrap();
        assert_eq!(fr.policy().compression, CompressionMode::FixedRate);
        // A codec without a compressed policy is a config error.
        assert!(Communicator::builder(8)
            .policy(ExecPolicy::nccl())
            .codec(CodecSpec::lossless())
            .build()
            .is_err());
    }

    #[test]
    fn dispatch_honors_tuned_per_leg_codecs_on_thin_uplinks() {
        use crate::net::LinkModel;
        // The schedule-level acceptance scenario end to end: 512 ranks
        // as 4x16x8, a starved rack uplink — the tuner trades kernel
        // time for wire bytes on the top tier only, and the dispatched
        // plan carries the mix (ambient codec unset ⇒ tuned picks
        // survive).
        let tree = TierTree::new(512, &[4, 16, 8]).unwrap();
        let mut spec = ClusterSpec::with_tiers(tree, ExecPolicy::gzccl());
        spec.uplinks = vec![LinkModel::new(25e-6, 1.25e9)];
        let comm = Communicator::from_spec(spec);
        let inputs: Vec<DeviceBuf> = (0..512).map(|_| DeviceBuf::Virtual(64 << 20)).collect();
        let out = comm.allreduce(inputs, &CollectiveSpec::auto()).unwrap();
        assert_eq!(out.algo, Algo::Hierarchical);
        let top: Vec<CodecSpec> = out
            .legs
            .iter()
            .filter(|l| l.tier == 2 && l.exec.compresses())
            .map(|l| l.exec.codec)
            .collect();
        assert!(
            top.contains(&CodecSpec::rle_rice()),
            "rack-uplink legs should trade kernel time for ratio: {top:?}"
        );
        let lower: Vec<CodecSpec> = out
            .legs
            .iter()
            .filter(|l| l.tier <= 1 && l.exec.compresses())
            .map(|l| l.exec.codec)
            .collect();
        assert!(
            lower.iter().all(|c| *c == CodecSpec::cuszp()),
            "NIC-tier legs keep the canonical codec: {lower:?}"
        );
    }

    #[test]
    fn telemetry_attached_for_compressed_real_runs() {
        let comm = Communicator::builder(4).error_bound(1e-3).build().unwrap();
        let out = comm
            .allreduce(real_inputs(4, 256, 9), &CollectiveSpec::auto())
            .unwrap();
        let acc = out
            .accuracy
            .expect("telemetry must run on real compressed payloads");
        assert_eq!(acc.within_bound(), Some(true), "observed {acc:?}");
        assert!(acc.samples > 0);
        for c in &out.counters {
            assert_eq!(c.observed_max_err, Some(acc.observed_max_err));
            assert!(c.predicted_err_bound.is_some());
        }
        // Virtual payloads: no telemetry (nothing real to compare).
        let virt: Vec<DeviceBuf> = (0..4).map(|_| DeviceBuf::Virtual(256)).collect();
        let vr = comm.allreduce(virt, &CollectiveSpec::auto()).unwrap();
        assert!(vr.accuracy.is_none());
        assert!(vr.counters[0].observed_max_err.is_none());
        // Uncompressed policies: no telemetry either.
        let nc = Communicator::builder(4).policy(ExecPolicy::nccl()).build().unwrap();
        assert!(nc
            .allreduce(real_inputs(4, 64, 9), &CollectiveSpec::auto())
            .unwrap()
            .accuracy
            .is_none());
    }

    #[test]
    fn tiers_builder_and_schedule_record() {
        let comm = Communicator::builder(24)
            .tiers(&[2, 3, 4])
            .error_bound(1e-3)
            .build()
            .unwrap();
        assert_eq!(comm.tiers().widths(), &[2, 3, 4]);
        assert_eq!(comm.cluster().topo.gpus_per_node(), 2);
        assert_eq!(comm.cluster().uplinks.len(), 1, "one uplink tier above node level");
        let out = comm
            .allreduce(
                real_inputs(24, 64, 3),
                &CollectiveSpec::forced(Algo::Hierarchical),
            )
            .unwrap();
        let sched = out
            .schedule
            .as_ref()
            .expect("hierarchical dispatch records its schedule");
        assert!(sched.tree.depth() >= 2);
        // The prediction attached to telemetry is the executed
        // schedule's own amplification.
        let acc = out.accuracy.expect("real compressed payloads probe");
        assert_eq!(
            acc.prediction.bound(),
            Some(sched.amplification() * comm.cluster().error_bound)
        );
        assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
        // Non-hierarchical dispatch carries no schedule.
        let flat = comm
            .allreduce(real_inputs(24, 64, 4), &CollectiveSpec::forced(Algo::Ring))
            .unwrap();
        assert!(flat.schedule.is_none());
        // A tier spec that does not cover the ranks is a build error.
        assert!(Communicator::builder(24).tiers(&[2, 2]).build().is_err());
    }

    #[test]
    fn relative_target_and_tiered_plan() {
        use crate::accuracy::AccuracyTarget;
        // RelError resolves against the declared value range at build.
        let comm = Communicator::builder(32)
            .tiers(&[2, 4, 4])
            .accuracy_target(AccuracyTarget::RelError(1e-3))
            .value_range(2.0)
            .build()
            .unwrap();
        let plan = *comm.budget_plan().unwrap();
        assert!((plan.per_call_abs - 2e-3).abs() < 1e-15);
        // Multi-tier budget: the per-tier split is attached and sound.
        let tiered = comm.tiered_plan().expect("3-tier budget splits across tiers");
        assert!(tiered.predicted_total() <= plan.per_call_abs * (1.0 + 1e-9));
        assert!(tiered.tier(0).is_none(), "tier 0 stays raw");
        assert!(tiered.tier(1).is_some() && tiered.tier(2).is_some());
        // Without a range the relative target is rejected at build.
        assert!(Communicator::builder(32)
            .accuracy_target(AccuracyTarget::RelError(1e-3))
            .build()
            .is_err());
    }

    #[test]
    fn budgeted_reduce_scatter_dispatches_hierarchical() {
        use crate::accuracy::AccuracyTarget;
        // PR 3 vetoed Reduce_scatter outright under tight budgets (its
        // only algorithm paid N−1 linear stages); the schedule engine
        // gives the veto a compliant fallback.
        let n = 32;
        let comm = Communicator::builder(n)
            .gpus_per_node(4)
            .accuracy_target(AccuracyTarget::AbsError(1e-3))
            .build()
            .unwrap();
        let out = comm
            .reduce_scatter(real_inputs(n, 256, 8), &CollectiveSpec::auto())
            .unwrap();
        assert_eq!(out.algo, Algo::Hierarchical);
        assert!(out.auto_tuned);
        let acc = out.accuracy.expect("telemetry on real compressed payloads");
        assert_eq!(acc.within_bound(), Some(true), "{acc:?}");
        // The flat ring is still refused when forced.
        assert!(matches!(
            comm.reduce_scatter(real_inputs(n, 256, 9), &CollectiveSpec::forced(Algo::Ring)),
            Err(Error::Budget(_))
        ));
    }

    #[test]
    fn all_ops_run_through_the_communicator() {
        let n = 4;
        let d = 128;
        let comm = Communicator::builder(n)
            .error_bound(1e-3)
            .build()
            .unwrap();
        let spec = CollectiveSpec::auto();
        assert!(comm.allreduce(real_inputs(n, d, 1), &spec).is_ok());
        assert!(comm.allgather(real_inputs(n, d, 2), &spec).is_ok());
        assert!(comm.reduce_scatter(real_inputs(n, d, 3), &spec).is_ok());
        let rooted = |seed| {
            let mut v = real_inputs(1, d, seed);
            for _ in 1..n {
                v.push(DeviceBuf::Real(vec![]));
            }
            v
        };
        assert!(comm.scatter(rooted(4), &spec).is_ok());
        assert!(comm.bcast(rooted(5), &spec).is_ok());
    }
}
