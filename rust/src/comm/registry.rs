//! The `(Op, Algo)` → collective-function registry.
//!
//! The concrete free functions in [`crate::collectives`] stay exactly
//! as they are — plain functions over a [`crate::coordinator::RankCtx`]
//! — and this registry is the only place outside their own module that
//! names them. Everything above (communicator, experiments, apps, CLI)
//! dispatches through [`AlgoRegistry::resolve`].

use crate::collectives::{
    allgather_bruck, allgather_hierarchical, allgather_recursive_doubling, allgather_ring,
    allreduce_hierarchical, allreduce_recursive_doubling, allreduce_reduce_bcast, allreduce_ring,
    reduce_scatter_hierarchical, reduce_scatter_ring, Algo, BcastProg, Op, PlanProg,
    RootedDefaultProg, RootedProg, ScatterProg, SchedProg,
};
use crate::coordinator::{DeviceBuf, ProgFut, Program, RankCtx, RankProgram};
use crate::error::{Error, Result};
use crate::topo::{ExecPlan, LegExec, Schedule};

/// The single-rank no-op program: every collective is the identity.
struct Identity;

impl Program for Identity {
    fn run<'a>(&'a self, _ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move { Ok(input) })
    }
}

/// Wraps a flat program in the degenerate one-leg plan: the whole
/// collective runs inside leg 0 at the plan's bound.
struct Leg0 {
    exec: LegExec,
    inner: Box<RankProgram>,
}

impl Program for Leg0 {
    fn run<'a>(&'a self, ctx: &'a mut RankCtx, input: DeviceBuf) -> ProgFut<'a> {
        Box::pin(async move {
            ctx.begin_leg(0, self.exec);
            let out = self.inner.run(ctx, input).await;
            ctx.end_leg();
            out
        })
    }
}

/// Static registry of implemented `(Op, Algo)` pairs.
pub struct AlgoRegistry;

impl AlgoRegistry {
    /// The algorithms implemented for `op`, in preference order.
    ///
    /// [`Algo::Identity`] is deliberately absent: it is the tuner's
    /// internal decision for single-rank communicators, not an
    /// algorithm callers may force (forcing it on a real communicator
    /// would silently skip the collective).
    pub fn supported(op: Op) -> &'static [Algo] {
        match op {
            // `Binomial` realizes the staged reduce+bcast Allreduce
            // (the Cray-MPI-class baseline).
            Op::Allreduce => &[
                Algo::Ring,
                Algo::RecursiveDoubling,
                Algo::Hierarchical,
                Algo::Binomial,
            ],
            Op::Allgather => &[
                Algo::Ring,
                Algo::RecursiveDoubling,
                Algo::Bruck,
                Algo::Hierarchical,
            ],
            Op::ReduceScatter => &[Algo::Ring, Algo::Hierarchical],
            // The rooted descents: binomial trees by default, the
            // compress-once hierarchical descent on tiered clusters.
            Op::Scatter => &[Algo::Binomial, Algo::Hierarchical],
            Op::Bcast => &[Algo::Binomial, Algo::Hierarchical],
        }
    }

    /// Whether `(op, algo)` has an implementation.
    pub fn is_supported(op: Op, algo: Algo) -> bool {
        Self::supported(op).contains(&algo)
    }

    /// Resolve `(op, algo)` to a rank program. `total_elems` is the
    /// full-vector element count for Scatter (ignored elsewhere);
    /// `root` is the root rank for the one-to-all collectives.
    pub fn resolve(op: Op, algo: Algo, total_elems: usize, root: usize) -> Result<Box<RankProgram>> {
        Self::resolve_scheduled(op, algo, total_elems, root, None)
    }

    /// [`AlgoRegistry::resolve`] with a compiled [`ExecPlan`] — the
    /// dispatch-side entry point. Scheduled (hierarchical) plans run
    /// through [`run_plan`], each leg at its own bound; flat algorithms
    /// run their free function inside the plan's single degenerate leg
    /// ([`RankCtx::begin_leg`]), so per-call bound overrides and
    /// per-leg telemetry apply uniformly to every algorithm.
    pub fn resolve_planned(
        op: Op,
        algo: Algo,
        total_elems: usize,
        root: usize,
        plan: Option<ExecPlan>,
    ) -> Result<Box<RankProgram>> {
        let Some(plan) = plan else {
            return Self::resolve(op, algo, total_elems, root);
        };
        if plan.schedule.is_some() {
            return match (op, algo) {
                (Op::Allreduce | Op::ReduceScatter | Op::Allgather, Algo::Hierarchical) => {
                    Ok(Box::new(PlanProg(plan)))
                }
                // Rooted descents: the schedule must have been compiled
                // for this very op — a compiled Allreduce schedule has
                // the wrong leg kinds for a Bcast and must not run it.
                (Op::Scatter | Op::Bcast, Algo::Hierarchical) => {
                    if plan.schedule.as_ref().map(|s| s.op) != Some(op) {
                        return Err(Error::collective(format!(
                            "scheduled plan was compiled for {:?}, not {op:?}",
                            plan.schedule.as_ref().map(|s| s.op)
                        )));
                    }
                    Ok(Box::new(RootedProg {
                        plan,
                        total: total_elems,
                    }))
                }
                _ => Err(Error::collective(format!(
                    "no {algo:?} implementation for {op:?} (supported: {:?})",
                    Self::supported(op)
                ))),
            };
        }
        // Degenerate one-leg plan: the flat program runs wholly inside
        // leg 0, at the plan's bound.
        let exec = plan.legs.first().copied().unwrap_or_else(LegExec::raw);
        let inner = Self::resolve(op, algo, total_elems, root)?;
        Ok(Box::new(Leg0 { exec, inner }))
    }

    /// [`AlgoRegistry::resolve`] with an optional pre-compiled
    /// hierarchical [`Schedule`]: when the dispatcher already chose the
    /// per-tier legs (cost-tuned or budget-constrained), the program
    /// executes exactly that schedule at the cluster's ambient bound;
    /// without one the hierarchical free functions compile the
    /// min-error default from the cluster's own tier tree.
    /// Non-hierarchical pairs ignore the schedule. (Per-leg bounds go
    /// through [`AlgoRegistry::resolve_planned`] instead.)
    pub fn resolve_scheduled(
        op: Op,
        algo: Algo,
        total_elems: usize,
        root: usize,
        schedule: Option<Schedule>,
    ) -> Result<Box<RankProgram>> {
        match (op, algo, schedule) {
            (
                Op::Allreduce | Op::ReduceScatter | Op::Allgather,
                Algo::Hierarchical,
                Some(s),
            ) => {
                return Ok(Box::new(SchedProg(s)));
            }
            (_, Algo::Hierarchical, Some(_)) => {
                // The rooted descents need a total element count the
                // bare-schedule path does not carry; dispatch routes
                // them through `resolve_planned` instead.
                return Err(Error::collective(format!(
                    "scheduled {algo:?} for {op:?} must go through resolve_planned"
                )));
            }
            _ => {}
        }
        let program: Box<RankProgram> = match (op, algo) {
            // Single-rank communicators: every collective is a no-op.
            (_, Algo::Identity) => Box::new(Identity),
            (Op::Allreduce, Algo::Ring) => Box::new(allreduce_ring),
            (Op::Allreduce, Algo::RecursiveDoubling) => Box::new(allreduce_recursive_doubling),
            (Op::Allreduce, Algo::Hierarchical) => Box::new(allreduce_hierarchical),
            (Op::Allreduce, Algo::Binomial) => Box::new(allreduce_reduce_bcast),
            (Op::Allgather, Algo::Ring) => Box::new(allgather_ring),
            (Op::Allgather, Algo::RecursiveDoubling) => Box::new(allgather_recursive_doubling),
            (Op::Allgather, Algo::Bruck) => Box::new(allgather_bruck),
            (Op::Allgather, Algo::Hierarchical) => Box::new(allgather_hierarchical),
            (Op::ReduceScatter, Algo::Ring) => Box::new(reduce_scatter_ring),
            (Op::ReduceScatter, Algo::Hierarchical) => Box::new(reduce_scatter_hierarchical),
            (Op::Scatter, Algo::Binomial) => Box::new(ScatterProg {
                total: total_elems,
                root,
            }),
            (Op::Bcast, Algo::Binomial) => Box::new(BcastProg { root }),
            // Registry-default rooted descents: compile from the
            // cluster's own tier tree at run time.
            (Op::Scatter | Op::Bcast, Algo::Hierarchical) => Box::new(RootedDefaultProg {
                op,
                total: total_elems,
                root,
            }),
            (op, algo) => {
                return Err(Error::collective(format!(
                    "no {algo:?} implementation for {op:?} (supported: {:?})",
                    Self::supported(op)
                )))
            }
        };
        Ok(program)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_OPS: [Op; 5] = [
        Op::Allreduce,
        Op::Allgather,
        Op::ReduceScatter,
        Op::Scatter,
        Op::Bcast,
    ];

    #[test]
    fn every_supported_pair_resolves() {
        for op in ALL_OPS {
            for &algo in AlgoRegistry::supported(op) {
                assert!(AlgoRegistry::is_supported(op, algo));
                assert!(
                    AlgoRegistry::resolve(op, algo, 128, 0).is_ok(),
                    "{op:?}/{algo:?}"
                );
            }
        }
    }

    #[test]
    fn identity_resolves_everywhere_but_cannot_be_forced() {
        for op in ALL_OPS {
            assert!(AlgoRegistry::resolve(op, Algo::Identity, 128, 0).is_ok(), "{op:?}");
            assert!(
                !AlgoRegistry::is_supported(op, Algo::Identity),
                "{op:?} must not advertise Identity"
            );
        }
    }

    #[test]
    fn unsupported_pairs_rejected() {
        assert!(!AlgoRegistry::is_supported(Op::Scatter, Algo::Ring));
        assert!(AlgoRegistry::resolve(Op::Scatter, Algo::Ring, 128, 0).is_err());
        assert!(AlgoRegistry::resolve(Op::ReduceScatter, Algo::Bruck, 0, 0).is_err());
        // The schedule engine covers Hierarchical for every op: the
        // root-free trio plus the rooted descents.
        assert!(AlgoRegistry::is_supported(Op::Allgather, Algo::Hierarchical));
        assert!(AlgoRegistry::is_supported(Op::ReduceScatter, Algo::Hierarchical));
        assert!(AlgoRegistry::resolve(Op::Allgather, Algo::Hierarchical, 0, 0).is_ok());
        assert!(AlgoRegistry::is_supported(Op::Scatter, Algo::Hierarchical));
        assert!(AlgoRegistry::resolve(Op::Scatter, Algo::Hierarchical, 128, 0).is_ok());
        assert!(AlgoRegistry::resolve(Op::Bcast, Algo::Hierarchical, 128, 0).is_ok());
    }

    #[test]
    fn planned_resolve_covers_flat_and_scheduled_programs() {
        use crate::coordinator::CompressionMode;
        use crate::topo::{compile_min_error, TierTree};
        let tree = TierTree::new(8, &[2, 2, 2]).unwrap();
        let sched = compile_min_error(Op::Allreduce, &tree, true).unwrap();
        let plan = ExecPlan::uniform(sched, CompressionMode::ErrorBounded, 1e-3);
        assert!(AlgoRegistry::resolve_planned(
            Op::Allreduce,
            Algo::Hierarchical,
            0,
            0,
            Some(plan.clone())
        )
        .is_ok());
        // A rooted op rejects a plan compiled for a different op (an
        // Allreduce schedule has the wrong leg kinds for a Bcast)…
        assert!(
            AlgoRegistry::resolve_planned(Op::Bcast, Algo::Hierarchical, 0, 0, Some(plan))
                .is_err()
        );
        // …but accepts its own rooted compile.
        let rooted = crate::topo::compile_rooted(Op::Bcast, &tree, true, 3).unwrap();
        let rooted_plan = ExecPlan::uniform(rooted, CompressionMode::ErrorBounded, 1e-3);
        assert!(AlgoRegistry::resolve_planned(
            Op::Bcast,
            Algo::Hierarchical,
            128,
            3,
            Some(rooted_plan)
        )
        .is_ok());
        // Flat algorithms ride a degenerate one-leg plan…
        let flat = ExecPlan::flat(Op::Allreduce, CompressionMode::ErrorBounded, 1e-3);
        assert!(AlgoRegistry::resolve_planned(Op::Allreduce, Algo::Ring, 0, 0, Some(flat))
            .is_ok());
        // …and no plan falls back to the bare resolve.
        assert!(AlgoRegistry::resolve_planned(Op::Allreduce, Algo::Ring, 0, 0, None).is_ok());
    }

    #[test]
    fn scheduled_resolve_runs_the_compiled_legs() {
        use crate::topo::{compile_min_error, TierTree};
        let tree = TierTree::new(8, &[2, 2, 2]).unwrap();
        let sched = compile_min_error(Op::Allreduce, &tree, false).unwrap();
        assert!(AlgoRegistry::resolve_scheduled(
            Op::Allreduce,
            Algo::Hierarchical,
            0,
            0,
            Some(sched.clone())
        )
        .is_ok());
        // A schedule cannot graft Hierarchical onto a rooted op.
        assert!(AlgoRegistry::resolve_scheduled(
            Op::Bcast,
            Algo::Hierarchical,
            0,
            0,
            Some(sched)
        )
        .is_err());
    }
}
