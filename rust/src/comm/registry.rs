//! The `(Op, Algo)` → collective-function registry.
//!
//! The concrete free functions in [`crate::collectives`] stay exactly
//! as they are — plain functions over a [`crate::coordinator::RankCtx`]
//! — and this registry is the only place outside their own module that
//! names them. Everything above (communicator, experiments, apps, CLI)
//! dispatches through [`AlgoRegistry::resolve`].

use crate::collectives::{
    allgather_bruck, allgather_recursive_doubling, allgather_ring, allreduce_hierarchical,
    allreduce_recursive_doubling, allreduce_reduce_bcast, allreduce_ring, bcast_binomial,
    reduce_scatter_ring, scatter_binomial, Algo, Op,
};
use crate::coordinator::{DeviceBuf, RankCtx, RankProgram};
use crate::error::{Error, Result};

/// Static registry of implemented `(Op, Algo)` pairs.
pub struct AlgoRegistry;

impl AlgoRegistry {
    /// The algorithms implemented for `op`, in preference order.
    ///
    /// [`Algo::Identity`] is deliberately absent: it is the tuner's
    /// internal decision for single-rank communicators, not an
    /// algorithm callers may force (forcing it on a real communicator
    /// would silently skip the collective).
    pub fn supported(op: Op) -> &'static [Algo] {
        match op {
            // `Binomial` realizes the staged reduce+bcast Allreduce
            // (the Cray-MPI-class baseline).
            Op::Allreduce => &[
                Algo::Ring,
                Algo::RecursiveDoubling,
                Algo::Hierarchical,
                Algo::Binomial,
            ],
            Op::Allgather => &[Algo::Ring, Algo::RecursiveDoubling, Algo::Bruck],
            Op::ReduceScatter => &[Algo::Ring],
            Op::Scatter => &[Algo::Binomial],
            Op::Bcast => &[Algo::Binomial],
        }
    }

    /// Whether `(op, algo)` has an implementation.
    pub fn is_supported(op: Op, algo: Algo) -> bool {
        Self::supported(op).contains(&algo)
    }

    /// Resolve `(op, algo)` to a rank program. `total_elems` is the
    /// full-vector element count for Scatter (ignored elsewhere);
    /// `root` is the root rank for the one-to-all collectives.
    pub fn resolve(op: Op, algo: Algo, total_elems: usize, root: usize) -> Result<Box<RankProgram>> {
        let program: Box<RankProgram> = match (op, algo) {
            // Single-rank communicators: every collective is a no-op.
            (_, Algo::Identity) => {
                Box::new(|_ctx: &mut RankCtx, input: DeviceBuf| Ok(input))
            }
            (Op::Allreduce, Algo::Ring) => Box::new(allreduce_ring),
            (Op::Allreduce, Algo::RecursiveDoubling) => Box::new(allreduce_recursive_doubling),
            (Op::Allreduce, Algo::Hierarchical) => Box::new(allreduce_hierarchical),
            (Op::Allreduce, Algo::Binomial) => Box::new(allreduce_reduce_bcast),
            (Op::Allgather, Algo::Ring) => Box::new(allgather_ring),
            (Op::Allgather, Algo::RecursiveDoubling) => Box::new(allgather_recursive_doubling),
            (Op::Allgather, Algo::Bruck) => Box::new(allgather_bruck),
            (Op::ReduceScatter, Algo::Ring) => Box::new(reduce_scatter_ring),
            (Op::Scatter, Algo::Binomial) => Box::new(move |ctx: &mut RankCtx, input: DeviceBuf| {
                scatter_binomial(ctx, input, total_elems, root)
            }),
            (Op::Bcast, Algo::Binomial) => Box::new(move |ctx: &mut RankCtx, input: DeviceBuf| {
                bcast_binomial(ctx, input, root)
            }),
            (op, algo) => {
                return Err(Error::collective(format!(
                    "no {algo:?} implementation for {op:?} (supported: {:?})",
                    Self::supported(op)
                )))
            }
        };
        Ok(program)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_OPS: [Op; 5] = [
        Op::Allreduce,
        Op::Allgather,
        Op::ReduceScatter,
        Op::Scatter,
        Op::Bcast,
    ];

    #[test]
    fn every_supported_pair_resolves() {
        for op in ALL_OPS {
            for &algo in AlgoRegistry::supported(op) {
                assert!(AlgoRegistry::is_supported(op, algo));
                assert!(
                    AlgoRegistry::resolve(op, algo, 128, 0).is_ok(),
                    "{op:?}/{algo:?}"
                );
            }
        }
    }

    #[test]
    fn identity_resolves_everywhere_but_cannot_be_forced() {
        for op in ALL_OPS {
            assert!(AlgoRegistry::resolve(op, Algo::Identity, 128, 0).is_ok(), "{op:?}");
            assert!(
                !AlgoRegistry::is_supported(op, Algo::Identity),
                "{op:?} must not advertise Identity"
            );
        }
    }

    #[test]
    fn unsupported_pairs_rejected() {
        assert!(!AlgoRegistry::is_supported(Op::Scatter, Algo::Ring));
        assert!(AlgoRegistry::resolve(Op::Scatter, Algo::Ring, 128, 0).is_err());
        assert!(AlgoRegistry::resolve(Op::ReduceScatter, Algo::Bruck, 0, 0).is_err());
        assert!(!AlgoRegistry::is_supported(Op::Allgather, Algo::Hierarchical));
        assert!(AlgoRegistry::resolve(Op::Allgather, Algo::Hierarchical, 0, 0).is_err());
    }
}
