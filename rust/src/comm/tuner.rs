//! Policy-aware algorithm selection.
//!
//! The [`Tuner`] encodes the paper's §3.3.3 crossover model, extended
//! with a topology axis. Two knobs, both calibrated against the shapes
//! of Figs. 9–12:
//!
//! * **Compressed collectives** (`CompressionMode::{ErrorBounded,
//!   FixedRate}`): the ring Allreduce issues `2(N−1)` compression
//!   kernels over `D/N` chunks; once the chunk falls below the GPU
//!   utilization knee those kernels stagnate at their fixed-work floor
//!   (Fig. 3) and the whole-vector log-step schedules win. Ring is
//!   selected when `D/N ≥ chunk_knee_bytes`, i.e. the crossover
//!   message size grows **linearly with the rank count**.
//! * **Uncompressed baselines** (`CompressionMode::None`): the classic
//!   MPI latency-vs-bandwidth switch. Ring costs `2(N−1)` message
//!   latencies, recursive doubling `⌈log₂N⌉`; ring is selected when
//!   `D ≥ latency_knee_bytes · ⌈log₂N⌉`.
//!
//! **Topology-aware three-way model**
//! ([`Tuner::select_with_topology`]): on a multi-node cluster with
//! multi-GPU nodes (`nodes ≥ 2`, `gpus_per_node ≥ 2`) under a
//! compressed policy, the selection is flat ring / hierarchical rather
//! than flat ring / flat ReDoub. Below the ring crossover, the
//! two-level schedule dominates flat gZ-ReDoub outright: its internode
//! leg runs `⌈log₂ nodes⌉` whole-vector compressed exchanges (per-leg
//! payload `D`, always above the utilization knee) instead of
//! `⌈log₂ ranks⌉`, and its intranode legs are raw NVLink traffic with
//! no kernel cost at all. Above the crossover the flat ring keeps the
//! win: its `D/N` chunk kernels are saturated anyway and its wire
//! volume (`≈2D` per NIC) beats the hierarchical leg's
//! `⌈log₂ nodes⌉·D`. Uncompressed policies keep the two-way
//! latency/bandwidth switch — without kernel floors to amortize, the
//! hierarchical leader funnel saves too little to beat the flat
//! schedules in the bandwidth-bound regime.
//!
//! **Per-tier crossover** ([`Tuner::select_with_tiers`],
//! [`Tuner::plan_schedule`]): on an N-level [`crate::topo::TierTree`]
//! the decision is priced by the [`crate::topo::CostModel`] — every
//! collapsed depth of the tree is compiled
//! ([`crate::topo::compile_tuned`] picks ring vs. doubling **per
//! tier**) and estimated against the physical tree's oversubscribed
//! uplinks, alongside the flat ring and flat gZ-ReDoub. Two-tier trees
//! reduce exactly to the rule-based model above.
//!
//! Degenerate single-rank communicators short-circuit to
//! [`Algo::Identity`] — an explicit no-op decision — so `OpCounters`
//! records are not polluted with a phantom ring dispatch.
//!
//! Scatter and Bcast have a single binomial-tree algorithm; Allgather
//! under compression is always the ring (the gZCCL one-compression
//! invariant), and falls back to Bruck for latency-bound uncompressed
//! messages.

use crate::accuracy::budget::{complies_tiers, BudgetPlan};
use crate::collectives::{Algo, Op};
use crate::coordinator::{CompressionMode, ExecPolicy};
use crate::error::{Error, Result};
use crate::gpu::GpuModel;
use crate::net::Topology;
use crate::topo::{
    compile_min_error, compile_rooted, compile_tuned, estimate_flat_allgather, estimate_flat_redoub,
    estimate_flat_reduce_scatter, estimate_flat_ring, CostModel, Schedule, TierTree,
};

use super::registry::AlgoRegistry;

/// How a [`super::Communicator`] should choose the algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoHint {
    /// Let the [`Tuner`] decide from op, policy, size, scale and
    /// topology.
    Auto,
    /// Bypass the tuner and run exactly this algorithm.
    Force(Algo),
}

/// Per-call options of a collective: the root rank (Scatter/Bcast) and
/// the algorithm hint.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveSpec {
    /// Root rank for one-to-all collectives — any rank in `0..nranks`
    /// (the binomial trees rotate the rank space around it).
    pub root: usize,
    /// Algorithm selection hint.
    pub hint: AlgoHint,
}

impl CollectiveSpec {
    /// Tuner-selected algorithm, root 0.
    pub fn auto() -> Self {
        CollectiveSpec {
            root: 0,
            hint: AlgoHint::Auto,
        }
    }

    /// Forced algorithm, root 0.
    pub fn forced(algo: Algo) -> Self {
        CollectiveSpec {
            root: 0,
            hint: AlgoHint::Force(algo),
        }
    }

    /// From an explicit hint, root 0.
    pub fn hinted(hint: AlgoHint) -> Self {
        CollectiveSpec { root: 0, hint }
    }

    /// Override the root rank.
    pub fn with_root(mut self, root: usize) -> Self {
        self.root = root;
        self
    }
}

impl Default for CollectiveSpec {
    fn default() -> Self {
        Self::auto()
    }
}

/// The size/scale/policy/topology crossover model (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct Tuner {
    /// Minimum ring chunk (`D/N`) under compression for the ring to
    /// stay above the GPU utilization floor.
    pub chunk_knee_bytes: usize,
    /// Per-`log₂N`-step message-size knee for the uncompressed
    /// latency-vs-bandwidth switch.
    pub latency_knee_bytes: usize,
}

/// Compress-kernel utilization fraction that defines the ring chunk
/// knee: below it, a `D/N` chunk kernel is so dominated by its fixed
/// work that the whole-vector log-step schedules win. Calibrated once
/// against the shapes of Figs. 9–12 (≈1 MiB chunks on the A100 model);
/// the byte value itself is now *derived* from the
/// [`GpuModel`] cost curve via [`Tuner::for_gpu`], so recalibrating the
/// kernel model moves the crossover with it.
pub const RING_CHUNK_UTILIZATION: f64 = 0.005;

impl Default for Tuner {
    fn default() -> Self {
        Self::for_gpu(&GpuModel::a100())
    }
}

fn ceil_log2(n: usize) -> usize {
    (usize::BITS - (n.max(1) - 1).leading_zeros()) as usize
}

impl Tuner {
    /// A tuner with explicit knees (what-if studies and tests). This is
    /// the override constructor; [`Tuner::for_gpu`] derives the chunk
    /// knee from a device cost model instead.
    pub fn new(chunk_knee_bytes: usize, latency_knee_bytes: usize) -> Self {
        Tuner {
            chunk_knee_bytes,
            latency_knee_bytes,
        }
    }

    /// A tuner calibrated from a [`GpuModel`]: the compressed-ring
    /// chunk knee is the size at which the compression kernel reaches
    /// [`RING_CHUNK_UTILIZATION`] of streaming throughput — the point
    /// (on the same curve as
    /// [`GpuModel::saturation_knee_bytes`]) where ring chunk kernels
    /// stop being pure fixed-work floors.
    pub fn for_gpu(gpu: &GpuModel) -> Self {
        Tuner {
            chunk_knee_bytes: gpu.compress.bytes_at_utilization(RING_CHUNK_UTILIZATION) as usize,
            latency_knee_bytes: 256 << 10, // 256 KiB per log-step
        }
    }

    /// Total Allreduce message size (bytes) at and above which the ring
    /// is selected for `(policy, nranks)`. Grows linearly with `nranks`
    /// under compression, logarithmically without. (For `nranks ≤ 1`
    /// the crossover is vacuous — [`Tuner::select`] short-circuits to
    /// [`Algo::Identity`] before consulting it.)
    pub fn allreduce_crossover_bytes(&self, policy: ExecPolicy, nranks: usize) -> usize {
        if nranks <= 1 {
            return 0;
        }
        if policy.compression == CompressionMode::None {
            self.latency_knee_bytes * ceil_log2(nranks)
        } else {
            self.chunk_knee_bytes * nranks
        }
    }

    /// Pick the algorithm for `op` over a `msg_bytes` payload on
    /// `nranks` ranks under `policy`, **topology-oblivious** (flat
    /// schedules only). Prefer [`Tuner::select_with_topology`], which
    /// adds the hierarchical candidate when the layout supports it.
    pub fn select(&self, op: Op, policy: ExecPolicy, nranks: usize, msg_bytes: usize) -> Algo {
        if nranks <= 1 {
            // Explicit no-op decision: every collective on a one-rank
            // communicator is the identity.
            return Algo::Identity;
        }
        match op {
            Op::Allreduce => {
                if msg_bytes >= self.allreduce_crossover_bytes(policy, nranks) {
                    Algo::Ring
                } else {
                    Algo::RecursiveDoubling
                }
            }
            Op::Allgather => {
                if policy.compression != CompressionMode::None {
                    // gZCCL invariant: ring compresses each origin
                    // block exactly once; log-step algorithms
                    // recompress doubling aggregates.
                    Algo::Ring
                } else if msg_bytes < self.latency_knee_bytes * ceil_log2(nranks) {
                    Algo::Bruck
                } else {
                    Algo::Ring
                }
            }
            Op::ReduceScatter => Algo::Ring,
            Op::Scatter | Op::Bcast => Algo::Binomial,
        }
    }

    /// Topology-aware selection: the three-way flat-ring /
    /// hierarchical / gZ-ReDoub model for Allreduce (see module docs),
    /// falling back to [`Tuner::select`] for every other op and for
    /// layouts with a single node or single-GPU nodes.
    pub fn select_with_topology(
        &self,
        op: Op,
        policy: ExecPolicy,
        topo: &Topology,
        msg_bytes: usize,
    ) -> Algo {
        let n = topo.ranks();
        if n <= 1 {
            return Algo::Identity;
        }
        if op == Op::Allreduce
            && policy.compression != CompressionMode::None
            && topo.nodes() >= 2
            && topo.gpus_per_node() >= 2
        {
            // Three-way model, compressed multi-node multi-GPU layout:
            // ring above its chunk knee (saturated kernels, minimal
            // wire volume); hierarchical below it (⌈log₂ nodes⌉
            // whole-vector kernel stages, NVLink-only intranode hops).
            return if msg_bytes / n >= self.chunk_knee_bytes {
                Algo::Ring
            } else {
                Algo::Hierarchical
            };
        }
        self.select(op, policy, n, msg_bytes)
    }

    /// Compile the hierarchical schedule the cost model prefers for
    /// `op` on `tree`: per-tier legs from
    /// [`crate::topo::compile_tuned`] (ring vs. doubling per tier —
    /// the per-tier crossover), and the schedule **depth** chosen by
    /// estimated makespan over every [`TierTree::collapsed`] view — a
    /// deep tree may still be best served by its two-level collapse
    /// (e.g. when the payload is tiny and extra tiers only add
    /// latency).
    pub fn plan_schedule(
        &self,
        op: Op,
        policy: ExecPolicy,
        tree: &TierTree,
        cost: &CostModel,
        msg_bytes: usize,
    ) -> Result<Schedule> {
        let compressed = policy.compression != CompressionMode::None;
        let depths: Vec<usize> = if tree.depth() <= 2 {
            vec![tree.depth()]
        } else {
            (2..=tree.depth()).collect()
        };
        let mut best: Option<(f64, Schedule)> = None;
        for d in depths {
            let sched = compile_tuned(op, &tree.collapsed(d), compressed, msg_bytes, cost)?;
            let c = sched.estimate_makespan(tree, cost, msg_bytes);
            let better = match &best {
                None => true,
                Some((bc, _)) => c < *bc,
            };
            if better {
                best = Some((c, sched));
            }
        }
        Ok(best.expect("at least one depth candidate").1)
    }

    /// Tier-aware selection over an N-level [`TierTree`]: on 2-tier
    /// layouts this is exactly [`Tuner::select_with_topology`]; on
    /// deeper trees (compressed policies) the decision is the cost
    /// model's — flat ring vs. flat gZ-ReDoub vs. the best compiled
    /// hierarchical schedule, each priced against the physical tree's
    /// oversubscribed uplinks.
    pub fn select_with_tiers(
        &self,
        op: Op,
        policy: ExecPolicy,
        tree: &TierTree,
        cost: &CostModel,
        msg_bytes: usize,
    ) -> Algo {
        self.select_with_tiers_scheduled(op, policy, tree, cost, msg_bytes).0
    }

    /// [`Tuner::select_with_tiers`] that also hands back the compiled
    /// hierarchical schedule when that is the winning choice — the
    /// dispatcher executes exactly it, without re-running the depth
    /// sweep the selection already priced.
    pub fn select_with_tiers_scheduled(
        &self,
        op: Op,
        policy: ExecPolicy,
        tree: &TierTree,
        cost: &CostModel,
        msg_bytes: usize,
    ) -> (Algo, Option<Schedule>) {
        let n = tree.ranks();
        if n <= 1 {
            return (Algo::Identity, None);
        }
        if tree.depth() <= 2 {
            return (
                self.select_with_topology(op, policy, &tree.to_topology(), msg_bytes),
                None,
            );
        }
        if policy.compression == CompressionMode::None {
            // Without kernel floors to amortize the flat rules hold.
            return (self.select(op, policy, n, msg_bytes), None);
        }
        let hier = self.plan_schedule(op, policy, tree, cost, msg_bytes).ok();
        let hier_cost = hier
            .as_ref()
            .map_or(f64::INFINITY, |s| s.estimate_makespan(tree, cost, msg_bytes));
        match op {
            Op::Allreduce | Op::ReduceScatter => {
                let ring = if op == Op::Allreduce {
                    estimate_flat_ring(tree, cost, msg_bytes, true)
                } else {
                    // The flat ring Reduce_scatter pays only N−1
                    // rounds, not the Allreduce's 2(N−1).
                    estimate_flat_reduce_scatter(tree, cost, msg_bytes, true)
                };
                let redoub = if op == Op::Allreduce {
                    estimate_flat_redoub(tree, cost, msg_bytes, true)
                } else {
                    // No flat log-step Reduce_scatter is implemented.
                    f64::INFINITY
                };
                if hier_cost <= ring && hier_cost <= redoub {
                    (Algo::Hierarchical, hier)
                } else if ring <= redoub {
                    (Algo::Ring, None)
                } else {
                    (Algo::RecursiveDoubling, None)
                }
            }
            Op::Allgather => {
                // The flat ring already compresses each block once;
                // hierarchy only wins when uplink relief pays for the
                // extra crossings.
                if hier_cost < estimate_flat_allgather(tree, cost, msg_bytes, true) {
                    (Algo::Hierarchical, hier)
                } else {
                    (Algo::Ring, None)
                }
            }
            Op::Scatter | Op::Bcast => (self.select(op, policy, n, msg_bytes), None),
        }
    }

    /// Topology-aware selection under an accuracy budget (the
    /// **accuracy veto**): the performance-preferred algorithm is taken
    /// only if its worst-case predicted error fits the plan's per-call
    /// budget; otherwise fall back through the remaining candidates in
    /// descending performance preference and pick the first compliant
    /// one. Accuracy is a selection axis alongside makespan — an
    /// algorithm whose stage count blows the budget is never returned.
    ///
    /// Errors when *no* implemented algorithm can certify the budget
    /// (e.g. Reduce_scatter's only algorithm pays `N−1` linear stages).
    pub fn select_within_budget(
        &self,
        op: Op,
        policy: ExecPolicy,
        topo: &Topology,
        msg_bytes: usize,
        root: usize,
        plan: &BudgetPlan,
    ) -> Result<Algo> {
        self.select_within_budget_tiers(
            op,
            policy,
            &TierTree::from(topo),
            &CostModel::default_a100(),
            msg_bytes,
            root,
            plan,
        )
        .map(|(algo, _)| algo)
    }

    /// [`Tuner::select_within_budget`] over an N-level [`TierTree`].
    /// Also hands back the **certified schedule** when the compliant
    /// choice is hierarchical: the min-error compile whose
    /// amplification the `complies` check walked — the dispatcher must
    /// execute exactly it (a cost-tuned recompile could carry more
    /// error than the budget certified).
    #[allow(clippy::too_many_arguments)]
    pub fn select_within_budget_tiers(
        &self,
        op: Op,
        policy: ExecPolicy,
        tree: &TierTree,
        cost: &CostModel,
        msg_bytes: usize,
        root: usize,
        plan: &BudgetPlan,
    ) -> Result<(Algo, Option<Schedule>)> {
        let compressed = policy.compression != CompressionMode::None;
        let certified = |algo: Algo| -> Result<Option<Schedule>> {
            if algo != Algo::Hierarchical {
                Ok(None)
            } else if matches!(op, Op::Scatter | Op::Bcast) {
                // Rooted descents compile around the dispatch root.
                Ok(Some(compile_rooted(op, tree, compressed, root)?))
            } else {
                Ok(Some(compile_min_error(op, tree, compressed)?))
            }
        };
        let preferred = self.select_with_tiers(op, policy, tree, cost, msg_bytes);
        if complies_tiers(plan, op, preferred, tree, root) {
            let sched = certified(preferred)?;
            return Ok((preferred, sched));
        }
        // Fallback order: fewest compression stages first (the veto
        // exists precisely because fewer stages mean less error). The
        // hierarchical Reduce_scatter is what gives tight budgets a
        // compliant fallback instead of a hard rejection.
        let candidates: &[Algo] = match op {
            Op::Allreduce => &[Algo::Hierarchical, Algo::RecursiveDoubling, Algo::Ring],
            Op::ReduceScatter => &[Algo::Hierarchical, Algo::Ring],
            _ => AlgoRegistry::supported(op),
        };
        for &algo in candidates {
            if algo != preferred
                && AlgoRegistry::is_supported(op, algo)
                && complies_tiers(plan, op, algo, tree, root)
            {
                let sched = certified(algo)?;
                return Ok((algo, sched));
            }
        }
        Err(Error::budget(format!(
            "no {op:?} algorithm satisfies the accuracy budget \
             (per-call |err| ≤ {:.3e} with planned eb {:.3e})",
            plan.per_call_abs, plan.eb
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: usize = 1 << 20;

    fn topo(ranks: usize, g: usize) -> Topology {
        Topology::new(ranks, g).unwrap()
    }

    #[test]
    fn default_knee_is_derived_from_the_gpu_model() {
        // ROADMAP item closed: the chunk knee comes from the cost
        // model's utilization curve, not a hard-coded 1 MiB.
        let t = Tuner::default();
        let g = GpuModel::a100();
        assert_eq!(
            t.chunk_knee_bytes,
            g.compress.bytes_at_utilization(RING_CHUNK_UTILIZATION) as usize
        );
        // Paper-calibrated ballpark: ~1 MiB ring chunks on the A100.
        assert!(
            ((1 << 20)..(2 << 20)).contains(&t.chunk_knee_bytes),
            "knee {} out of the calibrated band",
            t.chunk_knee_bytes
        );
        // A slower-launch GPU pushes the knee up; the explicit-override
        // constructor still pins it exactly.
        let mut slow = g;
        slow.compress.launch *= 4.0;
        assert!(Tuner::for_gpu(&slow).chunk_knee_bytes > t.chunk_knee_bytes);
        assert_eq!(Tuner::new(123, 456).chunk_knee_bytes, 123);
    }

    #[test]
    fn crossover_moves_with_message_size() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // 32 ranks: crossover at ≈32 MiB total (~1 MiB model-derived
        // chunks).
        assert_eq!(t.select(Op::Allreduce, p, 32, MIB), Algo::RecursiveDoubling);
        assert_eq!(t.select(Op::Allreduce, p, 32, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 32, 256 * MIB), Algo::Ring);
    }

    #[test]
    fn crossover_moves_with_nranks() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // The same 64 MiB message: ring chunks shrink with scale.
        assert_eq!(t.select(Op::Allreduce, p, 8, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 32, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 128, 64 * MIB), Algo::RecursiveDoubling);
        assert_eq!(t.select(Op::Allreduce, p, 512, 64 * MIB), Algo::RecursiveDoubling);
        assert!(
            t.allreduce_crossover_bytes(p, 128) > t.allreduce_crossover_bytes(p, 32),
            "compressed crossover must grow with rank count"
        );
    }

    #[test]
    fn crossover_moves_with_policy() {
        let t = Tuner::default();
        // 4 MiB on 32 ranks: 128 KiB chunks sit under the compression
        // knee (→ ReDoub for gZCCL), but an uncompressed NCCL-class
        // policy is bandwidth-bound there (→ ring).
        assert_eq!(
            t.select(Op::Allreduce, ExecPolicy::gzccl(), 32, 4 * MIB),
            Algo::RecursiveDoubling
        );
        assert_eq!(
            t.select(Op::Allreduce, ExecPolicy::nccl(), 32, 4 * MIB),
            Algo::Ring
        );
        // The nccl baseline never compresses, so its crossover is the
        // latency rule, independent of the compression knee.
        assert_eq!(
            t.allreduce_crossover_bytes(ExecPolicy::nccl(), 32),
            (256 << 10) * 5
        );
    }

    #[test]
    fn topology_enables_hierarchical_below_ring_crossover() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // 128 ranks / 4 per node: 64 MiB rings would run 512 KiB chunk
        // kernels (below the knee) → hierarchical.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &topo(128, 4), 64 * MIB),
            Algo::Hierarchical
        );
        // 256 MiB: 2 MiB ring chunks are saturated → flat ring.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &topo(128, 4), 256 * MIB),
            Algo::Ring
        );
        // Small messages on multi-node layouts also go hierarchical:
        // fewer kernel floors AND fewer internode latencies.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &topo(128, 4), MIB),
            Algo::Hierarchical
        );
    }

    #[test]
    fn degenerate_layouts_fall_back_to_flat_model() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // Single node: no internode leg to save on.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &topo(4, 4), MIB),
            Algo::RecursiveDoubling
        );
        // One GPU per node: hierarchical degenerates to flat ReDoub.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &topo(32, 1), MIB),
            Algo::RecursiveDoubling
        );
        // Uncompressed policies keep the two-way switch.
        assert_eq!(
            t.select_with_topology(Op::Allreduce, ExecPolicy::nccl(), &topo(128, 4), 64 * MIB),
            Algo::Ring
        );
        // Non-Allreduce ops are unaffected by topology.
        assert_eq!(
            t.select_with_topology(Op::Allgather, p, &topo(128, 4), 64 * MIB),
            Algo::Ring
        );
    }

    #[test]
    fn allgather_compressed_always_ring() {
        let t = Tuner::default();
        for bytes in [1usize << 10, MIB, 600 * MIB] {
            assert_eq!(t.select(Op::Allgather, ExecPolicy::gzccl(), 64, bytes), Algo::Ring);
        }
        // Uncompressed + tiny → Bruck.
        assert_eq!(
            t.select(Op::Allgather, ExecPolicy::nccl(), 64, 1 << 10),
            Algo::Bruck
        );
        assert_eq!(
            t.select(Op::Allgather, ExecPolicy::nccl(), 64, 600 * MIB),
            Algo::Ring
        );
    }

    #[test]
    fn rooted_ops_are_binomial() {
        let t = Tuner::default();
        assert_eq!(t.select(Op::Scatter, ExecPolicy::gzccl(), 64, MIB), Algo::Binomial);
        assert_eq!(t.select(Op::Bcast, ExecPolicy::cray_mpi(), 64, MIB), Algo::Binomial);
        assert_eq!(t.select(Op::ReduceScatter, ExecPolicy::gzccl(), 64, MIB), Algo::Ring);
    }

    #[test]
    fn accuracy_veto_overrides_performance_preference() {
        use crate::accuracy::{plan_auto, AccuracyTarget};
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        let layout = topo(32, 4);
        // Budget anchored on the hierarchical schedule (8 nodes → m=7).
        let plan = plan_auto(
            AccuracyTarget::AbsError(1e-3),
            1,
            &layout,
            CompressionMode::ErrorBounded,
        )
        .unwrap();
        // 256 MiB: performance alone says flat ring (8 MiB saturated
        // chunks)...
        assert_eq!(
            t.select_with_topology(Op::Allreduce, p, &layout, 256 * MIB),
            Algo::Ring
        );
        // ...but ring's 32 linear error stages blow the budget; the
        // veto rejects it and lands on the compliant hierarchical.
        assert_eq!(
            t.select_within_budget(Op::Allreduce, p, &layout, 256 * MIB, 0, &plan)
                .unwrap(),
            Algo::Hierarchical
        );
        // Reduce_scatter's ring pays 31 linear stages and used to be a
        // hard rejection; the hierarchical schedule is the compliant
        // fallback the ROADMAP asked for.
        assert_eq!(
            t.select_within_budget(Op::ReduceScatter, p, &layout, MIB, 0, &plan)
                .unwrap(),
            Algo::Hierarchical
        );
        // With no compliant algorithm at all the veto still errors: a
        // tighter-than-anchor per-call budget (anchor m=7, iterations
        // split below any schedule's reach is impossible here, so probe
        // an op whose only algorithms exceed the anchor).
        assert!(!crate::accuracy::complies(
            &plan,
            Op::ReduceScatter,
            Algo::Ring,
            &layout,
            0
        ));
        // Compress-once ops sail through.
        assert_eq!(
            t.select_within_budget(Op::Bcast, p, &layout, MIB, 0, &plan).unwrap(),
            Algo::Binomial
        );
    }

    #[test]
    fn tier_aware_selection_adds_the_depth_axis() {
        use crate::topo::{CostModel, LegKind, TierTree};
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        let cost = CostModel::default_a100();
        // 2-tier trees delegate to the existing crossover exactly.
        let two = TierTree::new(128, &[4, 32]).unwrap();
        assert_eq!(
            t.select_with_tiers(Op::Allreduce, p, &two, &cost, 64 * MIB),
            t.select_with_topology(Op::Allreduce, p, &topo(128, 4), 64 * MIB)
        );
        // The acceptance tree: 512 ranks, 4 GPUs/node, 16 nodes/rack,
        // 8 racks at 64 MiB → the 3-tier hierarchical schedule.
        let three = TierTree::new(512, &[4, 16, 8]).unwrap();
        assert_eq!(
            t.select_with_tiers(Op::Allreduce, p, &three, &cost, 64 * MIB),
            Algo::Hierarchical
        );
        let sched = t
            .plan_schedule(Op::Allreduce, p, &three, &cost, 64 * MIB)
            .unwrap();
        assert_eq!(sched.tree.depth(), 3, "tuner must keep the rack tier");
        assert!(sched.legs.iter().any(|l| l.tier == 2));
        // Per-tier leg choice: the 16-wide rack ascent runs in-group
        // doubling, not a sequential leader fold.
        assert_eq!(sched.legs[1].kind, LegKind::AllreduceRedoub);
        // Hierarchical Reduce_scatter is selected on deep trees too
        // (the flat ring's 1022 chunk kernels are floor-bound).
        assert_eq!(
            t.select_with_tiers(Op::ReduceScatter, p, &three, &cost, 64 * MIB),
            Algo::Hierarchical
        );
        // Uncompressed deep trees keep the flat latency/bandwidth rule.
        assert_eq!(
            t.select_with_tiers(Op::Allreduce, ExecPolicy::nccl(), &three, &cost, 64 * MIB),
            Algo::Ring
        );
        // Allgather's flat ring is already compress-once; hierarchy
        // must not be forced on it blindly (either answer is a ring
        // variant of some tree — assert it stays implemented).
        let ag = t.select_with_tiers(Op::Allgather, p, &three, &cost, 64 * MIB);
        assert!(AlgoRegistry::is_supported(Op::Allgather, ag), "{ag:?}");
    }

    #[test]
    fn single_rank_short_circuits_to_identity() {
        // Regression: `nranks <= 1` used to report `Algo::Ring` (the
        // crossover degenerates to 0), polluting OpCounters decision
        // records for degenerate communicators.
        let t = Tuner::default();
        for op in [Op::Allreduce, Op::Allgather, Op::ReduceScatter, Op::Scatter, Op::Bcast] {
            assert_eq!(t.select(op, ExecPolicy::gzccl(), 1, 0), Algo::Identity);
            assert_eq!(t.select(op, ExecPolicy::nccl(), 0, MIB), Algo::Identity);
        }
        assert_eq!(
            t.select_with_topology(Op::Allreduce, ExecPolicy::gzccl(), &topo(1, 4), MIB),
            Algo::Identity
        );
    }
}
