//! Policy-aware algorithm selection.
//!
//! The [`Tuner`] encodes the paper's §3.3.3 crossover model. Two knobs,
//! both calibrated against the shapes of Figs. 9–12:
//!
//! * **Compressed collectives** (`CompressionMode::{ErrorBounded,
//!   FixedRate}`): the ring Allreduce issues `2(N−1)` compression
//!   kernels over `D/N` chunks; once the chunk falls below the GPU
//!   utilization knee those kernels stagnate at their fixed-work floor
//!   (Fig. 3) and gZ-ReDoub's `⌈log₂N⌉` whole-vector kernels win. Ring
//!   is selected when `D/N ≥ chunk_knee_bytes`, i.e. the crossover
//!   message size grows **linearly with the rank count**.
//! * **Uncompressed baselines** (`CompressionMode::None`): the classic
//!   MPI latency-vs-bandwidth switch. Ring costs `2(N−1)` message
//!   latencies, recursive doubling `⌈log₂N⌉`; ring is selected when
//!   `D ≥ latency_knee_bytes · ⌈log₂N⌉`.
//!
//! Scatter and Bcast have a single binomial-tree algorithm; Allgather
//! under compression is always the ring (the gZCCL one-compression
//! invariant), and falls back to Bruck for latency-bound uncompressed
//! messages.

use crate::collectives::{Algo, Op};
use crate::coordinator::{CompressionMode, ExecPolicy};

/// How a [`super::Communicator`] should choose the algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoHint {
    /// Let the [`Tuner`] decide from op, policy, size and scale.
    Auto,
    /// Bypass the tuner and run exactly this algorithm.
    Force(Algo),
}

/// Per-call options of a collective: the root rank (Scatter/Bcast) and
/// the algorithm hint.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveSpec {
    /// Root rank for one-to-all collectives (must currently be 0, the
    /// only root the binomial-tree implementations support).
    pub root: usize,
    /// Algorithm selection hint.
    pub hint: AlgoHint,
}

impl CollectiveSpec {
    /// Tuner-selected algorithm, root 0.
    pub fn auto() -> Self {
        CollectiveSpec {
            root: 0,
            hint: AlgoHint::Auto,
        }
    }

    /// Forced algorithm, root 0.
    pub fn forced(algo: Algo) -> Self {
        CollectiveSpec {
            root: 0,
            hint: AlgoHint::Force(algo),
        }
    }

    /// From an explicit hint, root 0.
    pub fn hinted(hint: AlgoHint) -> Self {
        CollectiveSpec { root: 0, hint }
    }

    /// Override the root rank.
    pub fn with_root(mut self, root: usize) -> Self {
        self.root = root;
        self
    }
}

impl Default for CollectiveSpec {
    fn default() -> Self {
        Self::auto()
    }
}

/// The size/scale/policy crossover model (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct Tuner {
    /// Minimum ring chunk (`D/N`) under compression for the ring to
    /// stay above the GPU utilization floor.
    pub chunk_knee_bytes: usize,
    /// Per-`log₂N`-step message-size knee for the uncompressed
    /// latency-vs-bandwidth switch.
    pub latency_knee_bytes: usize,
}

impl Default for Tuner {
    fn default() -> Self {
        Tuner {
            chunk_knee_bytes: 1 << 20,   // 1 MiB ring chunks
            latency_knee_bytes: 256 << 10, // 256 KiB per log-step
        }
    }
}

fn ceil_log2(n: usize) -> usize {
    (usize::BITS - (n.max(1) - 1).leading_zeros()) as usize
}

impl Tuner {
    /// A tuner with explicit knees (what-if studies and tests).
    pub fn new(chunk_knee_bytes: usize, latency_knee_bytes: usize) -> Self {
        Tuner {
            chunk_knee_bytes,
            latency_knee_bytes,
        }
    }

    /// Total Allreduce message size (bytes) at and above which the ring
    /// is selected for `(policy, nranks)`. Grows linearly with `nranks`
    /// under compression, logarithmically without.
    pub fn allreduce_crossover_bytes(&self, policy: ExecPolicy, nranks: usize) -> usize {
        if nranks <= 1 {
            return 0;
        }
        if policy.compression == CompressionMode::None {
            self.latency_knee_bytes * ceil_log2(nranks)
        } else {
            self.chunk_knee_bytes * nranks
        }
    }

    /// Pick the algorithm for `op` over a `msg_bytes` payload on
    /// `nranks` ranks under `policy`.
    pub fn select(&self, op: Op, policy: ExecPolicy, nranks: usize, msg_bytes: usize) -> Algo {
        match op {
            Op::Allreduce => {
                if msg_bytes >= self.allreduce_crossover_bytes(policy, nranks) {
                    Algo::Ring
                } else {
                    Algo::RecursiveDoubling
                }
            }
            Op::Allgather => {
                if policy.compression != CompressionMode::None {
                    // gZCCL invariant: ring compresses each origin
                    // block exactly once; log-step algorithms
                    // recompress doubling aggregates.
                    Algo::Ring
                } else if msg_bytes < self.latency_knee_bytes * ceil_log2(nranks) {
                    Algo::Bruck
                } else {
                    Algo::Ring
                }
            }
            Op::ReduceScatter => Algo::Ring,
            Op::Scatter | Op::Bcast => Algo::Binomial,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: usize = 1 << 20;

    #[test]
    fn crossover_moves_with_message_size() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // 32 ranks: crossover at 32 MiB total (1 MiB chunks).
        assert_eq!(t.select(Op::Allreduce, p, 32, MIB), Algo::RecursiveDoubling);
        assert_eq!(t.select(Op::Allreduce, p, 32, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 32, 256 * MIB), Algo::Ring);
    }

    #[test]
    fn crossover_moves_with_nranks() {
        let t = Tuner::default();
        let p = ExecPolicy::gzccl();
        // The same 64 MiB message: ring chunks shrink with scale.
        assert_eq!(t.select(Op::Allreduce, p, 8, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 32, 64 * MIB), Algo::Ring);
        assert_eq!(t.select(Op::Allreduce, p, 128, 64 * MIB), Algo::RecursiveDoubling);
        assert_eq!(t.select(Op::Allreduce, p, 512, 64 * MIB), Algo::RecursiveDoubling);
        assert!(
            t.allreduce_crossover_bytes(p, 128) > t.allreduce_crossover_bytes(p, 32),
            "compressed crossover must grow with rank count"
        );
    }

    #[test]
    fn crossover_moves_with_policy() {
        let t = Tuner::default();
        // 4 MiB on 32 ranks: 128 KiB chunks sit under the compression
        // knee (→ ReDoub for gZCCL), but an uncompressed NCCL-class
        // policy is bandwidth-bound there (→ ring).
        assert_eq!(
            t.select(Op::Allreduce, ExecPolicy::gzccl(), 32, 4 * MIB),
            Algo::RecursiveDoubling
        );
        assert_eq!(
            t.select(Op::Allreduce, ExecPolicy::nccl(), 32, 4 * MIB),
            Algo::Ring
        );
        // The nccl baseline never compresses, so its crossover is the
        // latency rule, independent of the compression knee.
        assert_eq!(
            t.allreduce_crossover_bytes(ExecPolicy::nccl(), 32),
            (256 << 10) * 5
        );
    }

    #[test]
    fn allgather_compressed_always_ring() {
        let t = Tuner::default();
        for bytes in [1usize << 10, MIB, 600 * MIB] {
            assert_eq!(t.select(Op::Allgather, ExecPolicy::gzccl(), 64, bytes), Algo::Ring);
        }
        // Uncompressed + tiny → Bruck.
        assert_eq!(
            t.select(Op::Allgather, ExecPolicy::nccl(), 64, 1 << 10),
            Algo::Bruck
        );
        assert_eq!(
            t.select(Op::Allgather, ExecPolicy::nccl(), 64, 600 * MIB),
            Algo::Ring
        );
    }

    #[test]
    fn rooted_ops_are_binomial() {
        let t = Tuner::default();
        assert_eq!(t.select(Op::Scatter, ExecPolicy::gzccl(), 64, MIB), Algo::Binomial);
        assert_eq!(t.select(Op::Bcast, ExecPolicy::cray_mpi(), 64, MIB), Algo::Binomial);
        assert_eq!(t.select(Op::ReduceScatter, ExecPolicy::gzccl(), 64, MIB), Algo::Ring);
    }

    #[test]
    fn single_rank_degenerates_to_ring() {
        let t = Tuner::default();
        assert_eq!(t.select(Op::Allreduce, ExecPolicy::gzccl(), 1, 0), Algo::Ring);
    }
}
